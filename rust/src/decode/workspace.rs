//! Workspace-reused decode engine — the zero-allocation trial pipeline.
//!
//! Every figure point in the paper averages over thousands of trials,
//! and each trial used to allocate the straggler index set, the
//! submatrix A (three fresh `Vec`s in `select_columns`), the row-sum
//! buffer, and all LSQR iteration vectors. A [`DecodeWorkspace`] owns
//! all of that scratch — one per worker thread, handed to the
//! Monte-Carlo engine via `MonteCarlo::mean_ws` — so the steady-state
//! trial loop performs **zero heap allocations** (pinned by the
//! `zero_alloc` integration test).
//!
//! The centerpiece is the fused path [`err1_from_supports`]: the
//! paper's own §2.2 observation that one-step decoding is *streamable*
//! means `err_1(A) = ||ρ A 1_r − 1_k||²` needs only the row coverage
//! counts, which can be accumulated straight from G's columns — A is
//! never materialized. The accumulation visits the selected columns in
//! order, exactly like `select_columns` + `row_sums` would, so the
//! fused and materialized paths are bit-identical (pinned by the
//! `decode_parity` integration test).

//! Two PR-2 additions extend the pipeline:
//!
//! * **CSR mirror** — [`DecodeWorkspace::mirror_csr`] caches a
//!   row-major twin of G (built once per G via `to_csr_into`), and
//!   [`DecodeWorkspace::err1_streamed`] computes err_1 in one
//!   contiguous sweep over it (blocked 4-lane row reductions) instead
//!   of scattering through CSC columns. For boolean G — every code the
//!   paper constructs — coverage counts are integers, so the streamed
//!   value is bit-identical to the fused CSC path.
//! * **Allocation-free re-draw** — the `*_redraw_trial` methods re-draw
//!   G itself through [`GradientCode::assignment_into`] into a
//!   workspace-owned matrix, so schemes that sample a fresh G every
//!   trial (BGC, rBGC, s-regular) also run with zero steady-state heap
//!   traffic. RNG consumption matches the historical
//!   `assignment` + `*_trial` sequence, so seeded results are unchanged.
//!
//! The scenario-spine refactor adds `*_with` variants of every trial
//! method taking a [`StragglerModel`]: straggler selection goes through
//! [`StragglerModel::non_stragglers_into`] into the workspace-owned
//! [`StragglerScratch`] instead of the hard-coded uniform draw. A
//! uniform model *is* `Rng::sample_indices_into` (same RNG stream, same
//! order), so the `*_with` paths are bit-identical to the r-based
//! methods under the default scenario; latency-deadline and adversarial
//! models plug in without touching the decode side. Latency models also
//! record the gather wall-clock ([`DecodeWorkspace::last_gather_time`])
//! — the time axis of the `repro scenario` time-to-accuracy sweeps.

use crate::codes::{AssignmentScratch, GradientCode};
use crate::decode::incremental::IncrementalDecoder;
use crate::linalg::{blocked, lsqr_with, CscMatrix, CsrMatrix, LsqrOptions, LsqrWorkspace};
use crate::stragglers::{StragglerModel, StragglerScratch};
use crate::util::Rng;

/// err_1(A) computed directly from G plus the non-straggler index set,
/// in O(k + nnz(A)), without materializing A. `row_acc` is the reused
/// coverage buffer (resized to `g.rows`, capacity kept).
///
/// Accumulation order matches `select_columns(ns)` + `row_sums()`
/// exactly, so results are bit-identical to the materialized path.
pub fn err1_from_supports(
    g: &CscMatrix,
    non_stragglers: &[usize],
    rho: f64,
    row_acc: &mut Vec<f64>,
) -> f64 {
    row_acc.clear();
    row_acc.resize(g.rows, 0.0);
    for &j in non_stragglers {
        assert!(j < g.cols, "column {j} out of bounds ({})", g.cols);
        for p in g.col_ptr[j]..g.col_ptr[j + 1] {
            row_acc[g.row_idx[p]] += g.vals[p];
        }
    }
    row_acc.iter().map(|&v| (rho * v - 1.0).powi(2)).sum()
}

/// err_1 streamed row-major over a CSR mirror of G: `col_count[j]` is
/// the selection multiplicity of column j (0 for stragglers), and each
/// row's coverage is a contiguous gather-reduce
/// ([`blocked::masked_row_sum`]) — no row-indexed scatter at all.
///
/// For boolean G the per-row coverage is an exact integer, so the
/// result is bit-identical to [`err1_from_supports`] on the same
/// selection (pinned by `tests/decode_parity.rs`); for weighted G the
/// two paths agree to rounding only.
pub fn err1_streamed_counts(g: &CsrMatrix, col_count: &[u32], rho: f64) -> f64 {
    assert_eq!(col_count.len(), g.cols, "count length != cols");
    let mut total = 0.0;
    for i in 0..g.rows {
        let lo = g.row_ptr[i];
        let hi = g.row_ptr[i + 1];
        let cov = blocked::masked_row_sum(&g.vals[lo..hi], &g.col_idx[lo..hi], col_count);
        total += (rho * cov - 1.0).powi(2);
    }
    total
}

/// Per-thread scratch for the straggler→decode trial pipeline.
///
/// All buffers grow to the largest instance seen and are then reused;
/// after a warmup trial, running more trials of the same shape does no
/// heap allocation at all.
#[derive(Clone, Debug)]
pub struct DecodeWorkspace {
    /// Materialized submatrix A (only the optimal path needs it).
    a: CscMatrix,
    /// Row coverage / row-sum accumulator (length k).
    row_acc: Vec<f64>,
    /// RHS ones vector 1_k for LSQR.
    ones: Vec<f64>,
    /// Warm-start vector (ρ · 1_r) for the optimal decoder.
    x0: Vec<f64>,
    /// Straggler-selection scratch (Fisher-Yates pool, selected index
    /// set, latency draws, order-statistic buffer, gather time) — the
    /// [`StragglerModel::non_stragglers_into`] half of the spine.
    stragglers: StragglerScratch,
    /// LSQR iteration vectors.
    lsqr: LsqrWorkspace,
    /// Workspace-owned G for the allocation-free re-draw trials.
    g: CscMatrix,
    /// Constructor scratch for [`GradientCode::assignment_into`].
    scratch: AssignmentScratch,
    /// Cached CSR mirror of the caller's G (see
    /// [`DecodeWorkspace::mirror_csr`]).
    g_csr: CsrMatrix,
    /// Per-column selection multiplicities for the streamed err_1 pass.
    col_count: Vec<u32>,
    /// Arrival-ordered streaming decode state (the anytime paths); see
    /// [`crate::decode::incremental`] for the prefix-parity contract.
    incremental: IncrementalDecoder,
}

impl Default for DecodeWorkspace {
    fn default() -> Self {
        Self::new()
    }
}

impl DecodeWorkspace {
    pub fn new() -> Self {
        DecodeWorkspace {
            a: CscMatrix::empty(),
            row_acc: Vec::new(),
            ones: Vec::new(),
            x0: Vec::new(),
            stragglers: StragglerScratch::new(),
            lsqr: LsqrWorkspace::new(),
            g: CscMatrix::empty(),
            scratch: AssignmentScratch::new(),
            g_csr: CsrMatrix::empty(),
            col_count: Vec::new(),
            incremental: IncrementalDecoder::new(),
        }
    }

    /// Pre-size every workspace-owned buffer for re-draw trials at
    /// (k, n, s), using the hard nnz bound k·n. Optional — buffers grow
    /// on demand anyway — but after this call the re-draw loops perform
    /// **zero** heap allocations from the very first trial (the strict
    /// regime `tests/zero_alloc.rs` pins), rather than settling after a
    /// warmup whose high-water mark can still be exceeded by an
    /// unusually dense Bernoulli draw.
    pub fn reserve_redraw(&mut self, k: usize, n: usize, s: usize) {
        let nnz_cap = k * n;
        self.g.col_ptr.reserve(n + 1);
        self.g.row_idx.reserve(nnz_cap);
        self.g.vals.reserve(nnz_cap);
        self.a.col_ptr.reserve(n + 1);
        self.a.row_idx.reserve(nnz_cap);
        self.a.vals.reserve(nnz_cap);
        self.scratch.col.reserve(k);
        self.scratch.stubs.reserve(n * s);
        self.scratch.adj_flat.reserve(n * s);
        self.scratch.deg.reserve(n);
        self.scratch.edges.reserve(n * s);
        self.scratch.bad.reserve(n * s / 2 + 1);
        self.row_acc.reserve(k);
        self.ones.reserve(k);
        self.x0.reserve(n);
        self.stragglers.reserve(n);
        self.col_count.reserve(n);
        self.incremental.reserve(k, n);
    }

    /// The non-straggler set sampled by the most recent `*_trial` call.
    pub fn last_non_stragglers(&self) -> &[usize] {
        &self.stragglers.idx
    }

    /// The gather wall-clock of the most recent `*_with` trial: when
    /// the master stopped waiting under the scenario's deadline policy.
    /// NaN for models with no time axis (uniform, adversarial) and for
    /// the legacy r-based trial methods.
    pub fn last_gather_time(&self) -> f64 {
        self.stragglers.gather_time
    }

    /// Fused one-step error for an explicit non-straggler set.
    pub fn err1_fused(&mut self, g: &CscMatrix, non_stragglers: &[usize], rho: f64) -> f64 {
        err1_from_supports(g, non_stragglers, rho, &mut self.row_acc)
    }

    /// Reference parity path: materialize A into the workspace
    /// submatrix, then run the row-sum pass (same result as
    /// [`DecodeWorkspace::err1_fused`], bit for bit).
    pub fn err1_materialized(&mut self, g: &CscMatrix, non_stragglers: &[usize], rho: f64) -> f64 {
        g.select_columns_into(non_stragglers, &mut self.a);
        self.a.row_sums_into(&mut self.row_acc);
        self.row_acc.iter().map(|&v| (rho * v - 1.0).powi(2)).sum()
    }

    /// Optimal decoding error err(A) for an explicit non-straggler set,
    /// via workspace-owned LSQR. `warm = Some(rho)` warm-starts at the
    /// one-step weights ρ·1_r (deterministic per figure point, so trial
    /// results stay independent of thread scheduling); `None` is
    /// bit-identical to `OptimalDecoder::err` on the materialized A.
    pub fn optimal_err(
        &mut self,
        g: &CscMatrix,
        non_stragglers: &[usize],
        opts: &LsqrOptions,
        warm: Option<f64>,
    ) -> f64 {
        g.select_columns_into(non_stragglers, &mut self.a);
        optimal_err_on_selected(&self.a, &mut self.ones, &mut self.x0, &mut self.lsqr, opts, warm)
    }

    /// One full Monte-Carlo trial of the one-step decoder: sample r
    /// uniform non-stragglers from G's columns, then compute err_1
    /// through the fused no-materialize path. Allocation-free at steady
    /// state. RNG consumption matches the historical
    /// `sample_indices` + `select_columns` + `err1` sequence, so seeded
    /// results are unchanged.
    pub fn onestep_trial(&mut self, g: &CscMatrix, r: usize, rho: f64, rng: &mut Rng) -> f64 {
        let scratch = &mut self.stragglers;
        rng.sample_indices_into(g.cols, r, &mut scratch.pool, &mut scratch.idx);
        scratch.gather_time = f64::NAN;
        err1_from_supports(g, &scratch.idx, rho, &mut self.row_acc)
    }

    /// One full one-step trial on a fixed G under a pluggable straggler
    /// model — the scenario spine's fixed-assignment path (adversarial
    /// scenarios, thm10-style contrasts). With a uniform model this is
    /// RNG-stream- and bit-identical to
    /// [`DecodeWorkspace::onestep_trial`] at the model's r.
    pub fn onestep_trial_with(
        &mut self,
        g: &CscMatrix,
        model: &dyn StragglerModel,
        rho: f64,
        rng: &mut Rng,
    ) -> f64 {
        model.non_stragglers_into(g.cols, rng, &mut self.stragglers);
        err1_from_supports(g, &self.stragglers.idx, rho, &mut self.row_acc)
    }

    /// One full Monte-Carlo trial of the optimal decoder: sample r
    /// uniform non-stragglers, materialize A into the reused buffer,
    /// solve with workspace LSQR. See [`DecodeWorkspace::optimal_err`]
    /// for the `warm` semantics.
    pub fn optimal_trial(
        &mut self,
        g: &CscMatrix,
        r: usize,
        opts: &LsqrOptions,
        warm: Option<f64>,
        rng: &mut Rng,
    ) -> f64 {
        let scratch = &mut self.stragglers;
        rng.sample_indices_into(g.cols, r, &mut scratch.pool, &mut scratch.idx);
        scratch.gather_time = f64::NAN;
        g.select_columns_into(&scratch.idx, &mut self.a);
        optimal_err_on_selected(&self.a, &mut self.ones, &mut self.x0, &mut self.lsqr, opts, warm)
    }

    /// One full optimal-decode trial on a fixed G under a pluggable
    /// straggler model; see [`DecodeWorkspace::onestep_trial_with`] for
    /// the fixed-assignment contract and
    /// [`DecodeWorkspace::optimal_err`] for `warm`.
    pub fn optimal_trial_with(
        &mut self,
        g: &CscMatrix,
        model: &dyn StragglerModel,
        opts: &LsqrOptions,
        warm: Option<f64>,
        rng: &mut Rng,
    ) -> f64 {
        model.non_stragglers_into(g.cols, rng, &mut self.stragglers);
        g.select_columns_into(&self.stragglers.idx, &mut self.a);
        optimal_err_on_selected(&self.a, &mut self.ones, &mut self.x0, &mut self.lsqr, opts, warm)
    }

    // ------------------------------------------------- CSR fast path

    /// Cache the CSR mirror of `g` for the streamed row-major decode
    /// paths. Build it **once per G** (O(nnz), reusing the workspace
    /// buffers) — the streamed methods below read the mirror only, so
    /// the caller must re-mirror after switching to a different G.
    /// The re-draw trials invalidate the mirror automatically.
    pub fn mirror_csr(&mut self, g: &CscMatrix) {
        g.to_csr_into(&mut self.g_csr);
    }

    /// The currently cached CSR mirror (empty until
    /// [`DecodeWorkspace::mirror_csr`] runs). Exposed for benches and
    /// parity tests.
    pub fn csr_mirror(&self) -> &CsrMatrix {
        &self.g_csr
    }

    fn invalidate_mirror(&mut self) {
        self.g_csr.rows = 0;
        self.g_csr.cols = 0;
        self.g_csr.row_ptr.clear();
        self.g_csr.row_ptr.push(0);
        self.g_csr.col_idx.clear();
        self.g_csr.vals.clear();
    }

    /// Split borrow for the fused redraw panel
    /// (`decode::PanelWorkspace::onestep_redraw_panel_with`): the
    /// workspace-owned G, the constructor scratch, and the straggler
    /// scratch as disjoint mutable borrows, so the panel can drive W
    /// `assignment_into` draws while scatter-accumulating into its own
    /// lane-strided coverage panel. Invalidates the CSR mirror (G is
    /// about to be overwritten lane by lane).
    pub(crate) fn redraw_parts(
        &mut self,
    ) -> (&mut CscMatrix, &mut AssignmentScratch, &mut StragglerScratch) {
        self.invalidate_mirror();
        (&mut self.g, &mut self.scratch, &mut self.stragglers)
    }

    /// err_1 for an explicit non-straggler set, streamed over the
    /// cached CSR mirror (one contiguous row-major pass; bit-identical
    /// to [`DecodeWorkspace::err1_fused`] on boolean G).
    pub fn err1_streamed(&mut self, non_stragglers: &[usize], rho: f64) -> f64 {
        let csr = &self.g_csr;
        assert!(
            csr.rows > 0 || csr.cols > 0,
            "call mirror_csr before the streamed decode paths"
        );
        self.col_count.clear();
        self.col_count.resize(csr.cols, 0);
        for &j in non_stragglers {
            assert!(j < csr.cols, "column {j} out of bounds ({})", csr.cols);
            self.col_count[j] += 1;
        }
        err1_streamed_counts(csr, &self.col_count, rho)
    }

    /// One full one-step Monte-Carlo trial on the CSR fast path:
    /// sample r uniform non-stragglers (identical RNG stream to
    /// [`DecodeWorkspace::onestep_trial`]), then stream err_1 over the
    /// cached mirror. Requires [`DecodeWorkspace::mirror_csr`] first.
    pub fn onestep_trial_streamed(&mut self, r: usize, rho: f64, rng: &mut Rng) -> f64 {
        assert!(
            self.g_csr.rows > 0 || self.g_csr.cols > 0,
            "call mirror_csr before the streamed decode paths"
        );
        let scratch = &mut self.stragglers;
        rng.sample_indices_into(self.g_csr.cols, r, &mut scratch.pool, &mut scratch.idx);
        scratch.gather_time = f64::NAN;
        self.col_count.clear();
        self.col_count.resize(self.g_csr.cols, 0);
        for &j in &scratch.idx {
            self.col_count[j] += 1;
        }
        err1_streamed_counts(&self.g_csr, &self.col_count, rho)
    }

    // ------------------------------------------- re-draw trial paths

    /// One full one-step trial for schemes that re-draw G every trial:
    /// draw G into the workspace ([`GradientCode::assignment_into`]),
    /// sample r non-stragglers, run the fused err_1 pass — all through
    /// reused buffers. RNG consumption matches the historical
    /// `code.assignment(rng)` + `onestep_trial(&g, ..)` sequence, so
    /// seeded figure/table values are unchanged.
    pub fn onestep_redraw_trial(
        &mut self,
        code: &dyn GradientCode,
        r: usize,
        rho: f64,
        rng: &mut Rng,
    ) -> f64 {
        self.invalidate_mirror();
        code.assignment_into(rng, &mut self.g, &mut self.scratch);
        let scratch = &mut self.stragglers;
        rng.sample_indices_into(self.g.cols, r, &mut scratch.pool, &mut scratch.idx);
        scratch.gather_time = f64::NAN;
        err1_from_supports(&self.g, &scratch.idx, rho, &mut self.row_acc)
    }

    /// [`DecodeWorkspace::onestep_redraw_trial`] with a pluggable
    /// straggler model — the scenario spine's re-draw path. With a
    /// uniform model this is RNG-stream- and bit-identical to the
    /// r-based method (the uniform draw *is*
    /// `Rng::sample_indices_into`), which keeps every historical CSV
    /// byte-identical under the default scenario; latency and
    /// adversarial models substitute their own selection.
    pub fn onestep_redraw_trial_with(
        &mut self,
        code: &dyn GradientCode,
        model: &dyn StragglerModel,
        rho: f64,
        rng: &mut Rng,
    ) -> f64 {
        self.invalidate_mirror();
        code.assignment_into(rng, &mut self.g, &mut self.scratch);
        model.non_stragglers_into(self.g.cols, rng, &mut self.stragglers);
        err1_from_supports(&self.g, &self.stragglers.idx, rho, &mut self.row_acc)
    }

    /// One full optimal-decode trial with per-trial G re-draw; see
    /// [`DecodeWorkspace::onestep_redraw_trial`] for the re-draw
    /// contract and [`DecodeWorkspace::optimal_err`] for `warm`.
    pub fn optimal_redraw_trial(
        &mut self,
        code: &dyn GradientCode,
        r: usize,
        opts: &LsqrOptions,
        warm: Option<f64>,
        rng: &mut Rng,
    ) -> f64 {
        self.invalidate_mirror();
        code.assignment_into(rng, &mut self.g, &mut self.scratch);
        let scratch = &mut self.stragglers;
        rng.sample_indices_into(self.g.cols, r, &mut scratch.pool, &mut scratch.idx);
        scratch.gather_time = f64::NAN;
        self.g.select_columns_into(&scratch.idx, &mut self.a);
        optimal_err_on_selected(&self.a, &mut self.ones, &mut self.x0, &mut self.lsqr, opts, warm)
    }

    /// [`DecodeWorkspace::optimal_redraw_trial`] with a pluggable
    /// straggler model; see
    /// [`DecodeWorkspace::onestep_redraw_trial_with`] for the spine
    /// contract and [`DecodeWorkspace::optimal_err`] for `warm`.
    pub fn optimal_redraw_trial_with(
        &mut self,
        code: &dyn GradientCode,
        model: &dyn StragglerModel,
        opts: &LsqrOptions,
        warm: Option<f64>,
        rng: &mut Rng,
    ) -> f64 {
        self.invalidate_mirror();
        code.assignment_into(rng, &mut self.g, &mut self.scratch);
        model.non_stragglers_into(self.g.cols, rng, &mut self.stragglers);
        self.g.select_columns_into(&self.stragglers.idx, &mut self.a);
        optimal_err_on_selected(&self.a, &mut self.ones, &mut self.x0, &mut self.lsqr, opts, warm)
    }

    /// One full one-step trial on the **column-normalized** submatrix:
    /// re-draw G, sample r non-stragglers, then compute
    /// `err_1 = ||ρ Â 1_r − 1_k||²` where Â is A with every column
    /// rescaled to sum to 1 (zero columns untouched) — without ever
    /// materializing Â. Accumulation order matches
    /// `codes::normalized::normalize_columns(&A)` followed by
    /// `OneStepDecoder::err1` exactly (per-column sequential total,
    /// same divisions, same row-scatter order, same final reduction),
    /// so the fused value is bit-identical to the historical allocating
    /// sequence — the ablation suite pins this. Callers pass the
    /// normalized step size ρ = k/r (`codes::normalized_rho`).
    pub fn onestep_normalized_redraw_trial(
        &mut self,
        code: &dyn GradientCode,
        r: usize,
        rho: f64,
        rng: &mut Rng,
    ) -> f64 {
        self.invalidate_mirror();
        code.assignment_into(rng, &mut self.g, &mut self.scratch);
        let scratch = &mut self.stragglers;
        rng.sample_indices_into(self.g.cols, r, &mut scratch.pool, &mut scratch.idx);
        scratch.gather_time = f64::NAN;
        self.g.select_columns_into(&scratch.idx, &mut self.a);
        err1_column_normalized(&self.a, rho, &mut self.row_acc)
    }

    /// [`DecodeWorkspace::onestep_normalized_redraw_trial`] with a
    /// pluggable straggler model (the scenario spine's normalized arm);
    /// uniform models reproduce the r-based method bit for bit.
    pub fn onestep_normalized_redraw_trial_with(
        &mut self,
        code: &dyn GradientCode,
        model: &dyn StragglerModel,
        rho: f64,
        rng: &mut Rng,
    ) -> f64 {
        self.invalidate_mirror();
        code.assignment_into(rng, &mut self.g, &mut self.scratch);
        model.non_stragglers_into(self.g.cols, rng, &mut self.stragglers);
        self.g.select_columns_into(&self.stragglers.idx, &mut self.a);
        err1_column_normalized(&self.a, rho, &mut self.row_acc)
    }

    /// Fixed-G variant of the normalized trial (adversarial standing
    /// assignments in the `normalization` ablation).
    pub fn onestep_normalized_trial_with(
        &mut self,
        g: &CscMatrix,
        model: &dyn StragglerModel,
        rho: f64,
        rng: &mut Rng,
    ) -> f64 {
        model.non_stragglers_into(g.cols, rng, &mut self.stragglers);
        g.select_columns_into(&self.stragglers.idx, &mut self.a);
        err1_column_normalized(&self.a, rho, &mut self.row_acc)
    }

    /// Re-draw G and materialize one straggler trial's A in the
    /// workspace, returning it — for decoders that need A itself (the
    /// Fig. 5 algorithmic curve). RNG consumption matches the
    /// historical `draw_non_straggler_matrix` exactly.
    pub fn redraw_submatrix(
        &mut self,
        code: &dyn GradientCode,
        r: usize,
        rng: &mut Rng,
    ) -> &CscMatrix {
        self.invalidate_mirror();
        code.assignment_into(rng, &mut self.g, &mut self.scratch);
        let scratch = &mut self.stragglers;
        rng.sample_indices_into(self.g.cols, r, &mut scratch.pool, &mut scratch.idx);
        scratch.gather_time = f64::NAN;
        self.g.select_columns_into(&scratch.idx, &mut self.a);
        &self.a
    }

    /// [`DecodeWorkspace::redraw_submatrix`] with a pluggable straggler
    /// model (the Fig. 5 algorithmic curve under a scenario); uniform
    /// models reproduce the r-based method bit for bit.
    pub fn redraw_submatrix_with(
        &mut self,
        code: &dyn GradientCode,
        model: &dyn StragglerModel,
        rng: &mut Rng,
    ) -> &CscMatrix {
        self.invalidate_mirror();
        code.assignment_into(rng, &mut self.g, &mut self.scratch);
        model.non_stragglers_into(self.g.cols, rng, &mut self.stragglers);
        self.g.select_columns_into(&self.stragglers.idx, &mut self.a);
        &self.a
    }

    /// Materialize one straggler trial's A from a **fixed** G under a
    /// pluggable model (adversarial standing assignments).
    pub fn select_submatrix_with(
        &mut self,
        g: &CscMatrix,
        model: &dyn StragglerModel,
        rng: &mut Rng,
    ) -> &CscMatrix {
        model.non_stragglers_into(g.cols, rng, &mut self.stragglers);
        g.select_columns_into(&self.stragglers.idx, &mut self.a);
        &self.a
    }

    /// Optimal decoding weights for the currently selected submatrix
    /// (the A left behind by the most recent `select_submatrix_with` /
    /// `*_trial*` call): a cold-start LSQR solve of `min_x ||A x − 1||`
    /// into workspace buffers, returning the workspace-owned solution.
    /// Bit-identical to `OptimalDecoder::weights` on the same A
    /// (`lsqr_with` with `x0 = None` is pinned bit-identical to `lsqr`,
    /// solution vector included) — the e2e coordinator's decode path.
    pub fn optimal_weights_selected(&mut self, opts: &LsqrOptions) -> &[f64] {
        self.ones.clear();
        self.ones.resize(self.a.rows, 1.0);
        lsqr_with(&self.a, &self.ones, opts, None, &mut self.lsqr);
        self.lsqr.x()
    }

    /// `||A x − 1_k||²` for the currently selected submatrix, into
    /// workspace buffers. Replicates `decode::decode_error`'s exact
    /// sequence (matvec, per-element `− 1.0`, then the *dense* scalar
    /// `norm2_sq`), so the value is bit-identical to the allocating
    /// path the coordinator used to call.
    pub fn decode_error_selected(&mut self, x: &[f64]) -> f64 {
        assert_eq!(x.len(), self.a.cols, "weight vector length mismatch");
        self.row_acc.clear();
        self.row_acc.resize(self.a.rows, 0.0);
        self.a.matvec_into(x, &mut self.row_acc);
        for v in self.row_acc.iter_mut() {
            *v -= 1.0;
        }
        crate::linalg::norm2_sq(&self.row_acc)
    }

    // -------------------------------------- incremental anytime paths

    /// The workspace-owned streaming decode state (see
    /// [`crate::decode::incremental`] for the prefix-parity,
    /// arrival-order, and warm-start contracts).
    pub fn incremental(&self) -> &IncrementalDecoder {
        &self.incremental
    }

    pub fn incremental_mut(&mut self) -> &mut IncrementalDecoder {
        &mut self.incremental
    }

    /// Message-arrival order of the most recent straggler draw
    /// (computed on demand; see
    /// [`StragglerScratch::compute_arrivals`]).
    pub fn last_arrival_order(&mut self) -> &[usize] {
        self.stragglers.compute_arrivals();
        &self.stragglers.arrivals
    }

    /// Per-worker latency draws of the most recent straggler draw
    /// (empty / stale for models with no time axis — check
    /// [`DecodeWorkspace::last_gather_time`] first).
    pub fn last_latencies(&self) -> &[f64] {
        &self.stragglers.latencies
    }

    /// Replay the most recent draw's survivors through the incremental
    /// decoder in arrival order, appending the **exact** err₁ after
    /// each arrival to `trace` (`trace[i]` is bit-identical to a batch
    /// decode on the first i+1 arrivals). Leaves the incremental state
    /// at the full survivor set for follow-up queries.
    pub fn incremental_trace_selected(
        &mut self,
        g: &CscMatrix,
        rho: f64,
        trace: &mut Vec<f64>,
    ) {
        self.stragglers.compute_arrivals();
        self.incremental.begin(g.rows, rho);
        for &j in &self.stragglers.arrivals {
            self.incremental.arrive(g, j);
            trace.push(self.incremental.err1());
        }
    }

    /// Adopt an arrival-order prefix of the most recent draw as *the*
    /// survivor set — the anytime stopping rules' commit step: `idx`
    /// becomes the sorted prefix, the gather clock becomes `gather`
    /// (the stopping arrival's latency, or the revised deadline), and
    /// A is re-materialized so the batch decode machinery
    /// ([`DecodeWorkspace::optimal_weights_selected`],
    /// [`DecodeWorkspace::decode_error_selected`]) runs on exactly the
    /// stopped prefix.
    pub fn adopt_arrival_prefix(&mut self, g: &CscMatrix, stop: usize, gather: f64) {
        assert!(
            stop <= self.stragglers.arrivals.len(),
            "prefix {stop} exceeds {} arrivals",
            self.stragglers.arrivals.len()
        );
        self.stragglers.idx.clear();
        let (idx, arrivals) = (&mut self.stragglers.idx, &self.stragglers.arrivals);
        idx.extend_from_slice(&arrivals[..stop]);
        idx.sort_unstable();
        self.stragglers.gather_time = gather;
        g.select_columns_into(&self.stragglers.idx, &mut self.a);
    }

    /// Arrival-ordered incremental re-draw trial: draw G, draw the
    /// survivor set, stream it through the incremental decoder in
    /// arrival order, return the exact err₁. Bit- and RNG-identical to
    /// [`DecodeWorkspace::onestep_redraw_trial_with`] for every
    /// straggler model: the coverage adds are exact (boolean G), so the
    /// arrival-order permutation cannot change the accumulated bits,
    /// and the final fold is the same row-order fold — the prefix-parity
    /// contract applied at the full prefix.
    pub fn onestep_incremental_redraw_trial_with(
        &mut self,
        code: &dyn GradientCode,
        model: &dyn StragglerModel,
        rho: f64,
        rng: &mut Rng,
    ) -> f64 {
        self.invalidate_mirror();
        code.assignment_into(rng, &mut self.g, &mut self.scratch);
        model.non_stragglers_into(self.g.cols, rng, &mut self.stragglers);
        self.stragglers.compute_arrivals();
        self.incremental.begin(self.g.rows, rho);
        for &j in &self.stragglers.arrivals {
            self.incremental.arrive(&self.g, j);
        }
        self.incremental.err1()
    }

    /// Anytime variant of the incremental re-draw trial, applying the
    /// two stopping rules to the arrival stream and returning
    /// `(gather_time, err1)` for the prefix actually consumed:
    ///
    /// * `revise = Some((at, to))` — mid-round deadline revision: at
    ///   wall-clock `at` the master revises its cutoff to `to`.
    ///   Messages already in hand can't be un-received, so the
    ///   effective cutoff is `max(at, to)`, clamped to the original
    ///   gather (revision only shortens; draws with no time axis
    ///   ignore it).
    /// * `target_err1 = Some(t)` — cancel-on-target: stop at the first
    ///   arrival whose **exact** err₁ satisfies err₁/k ≤ t; the gather
    ///   clock is that arrival's completion time.
    ///
    /// With both rules `None` this is exactly
    /// [`DecodeWorkspace::onestep_incremental_redraw_trial_with`].
    pub fn onestep_incremental_anytime_redraw_trial_with(
        &mut self,
        code: &dyn GradientCode,
        model: &dyn StragglerModel,
        rho: f64,
        target_err1: Option<f64>,
        revise: Option<(f64, f64)>,
        rng: &mut Rng,
    ) -> (f64, f64) {
        self.invalidate_mirror();
        code.assignment_into(rng, &mut self.g, &mut self.scratch);
        model.non_stragglers_into(self.g.cols, rng, &mut self.stragglers);
        self.stragglers.compute_arrivals();
        let k = self.g.rows;
        let mut gather = self.stragglers.gather_time;
        let mut n_keep = self.stragglers.arrivals.len();
        if let Some((at, to)) = revise {
            if !gather.is_nan() {
                let eff = gather.min(at.max(to));
                let (arrivals, latencies) =
                    (&self.stragglers.arrivals, &self.stragglers.latencies);
                n_keep = arrivals
                    .iter()
                    .take_while(|&&j| latencies[j] <= eff)
                    .count();
                gather = eff;
            }
        }
        self.incremental.begin(k, rho);
        let mut err1 = self.incremental.err1();
        let target = target_err1.map(|t| t * k as f64);
        for i in 0..n_keep {
            let j = self.stragglers.arrivals[i];
            self.incremental.arrive(&self.g, j);
            err1 = self.incremental.err1();
            if let Some(t) = target {
                if err1 <= t {
                    if !self.stragglers.gather_time.is_nan() {
                        gather = self.stragglers.latencies[j];
                    }
                    break;
                }
            }
        }
        (gather, err1)
    }

    /// Uniform-draw one-step trial decoded at an arrival prefix: draw r
    /// survivors (identical RNG stream to
    /// [`DecodeWorkspace::onestep_trial`]) but ingest only the first
    /// `prefix` of them in arrival (= draw) order, returning the exact
    /// err₁ of that prefix. `prefix == r` is bit-identical to the full
    /// batch trial — the serve daemon's `prefix` decode path.
    pub fn onestep_prefix_trial(
        &mut self,
        g: &CscMatrix,
        r: usize,
        prefix: usize,
        rho: f64,
        rng: &mut Rng,
    ) -> f64 {
        assert!(prefix <= r, "prefix {prefix} exceeds r {r}");
        let scratch = &mut self.stragglers;
        rng.sample_indices_into(g.cols, r, &mut scratch.pool, &mut scratch.idx);
        scratch.gather_time = f64::NAN;
        self.incremental.begin(g.rows, rho);
        for i in 0..prefix {
            let j = self.stragglers.idx[i];
            self.incremental.arrive(g, j);
        }
        self.incremental.err1()
    }

    /// Uniform-draw optimal trial decoded at an arrival prefix (same
    /// RNG stream as [`DecodeWorkspace::optimal_trial`]; `prefix == r`
    /// is bit-identical to it). See [`DecodeWorkspace::optimal_err`]
    /// for `warm`.
    pub fn optimal_prefix_trial(
        &mut self,
        g: &CscMatrix,
        r: usize,
        prefix: usize,
        opts: &LsqrOptions,
        warm: Option<f64>,
        rng: &mut Rng,
    ) -> f64 {
        assert!(prefix <= r, "prefix {prefix} exceeds r {r}");
        let scratch = &mut self.stragglers;
        rng.sample_indices_into(g.cols, r, &mut scratch.pool, &mut scratch.idx);
        scratch.gather_time = f64::NAN;
        g.select_columns_into(&self.stragglers.idx[..prefix], &mut self.a);
        optimal_err_on_selected(&self.a, &mut self.ones, &mut self.x0, &mut self.lsqr, opts, warm)
    }
}

/// One-step error on the **column-normalized** selected submatrix:
/// `err_1 = ||ρ Â 1_r − 1_k||²` where Â rescales every column of A to
/// sum to 1 (zero columns untouched) — without materializing Â.
/// Accumulation order matches `codes::normalized::normalize_columns`
/// followed by `OneStepDecoder::err1` exactly (per-column sequential
/// total, same divisions, same row-scatter order, same final
/// reduction), so the fused value is bit-identical to the historical
/// allocating sequence — the ablation suite pins this.
fn err1_column_normalized(a: &CscMatrix, rho: f64, row_acc: &mut Vec<f64>) -> f64 {
    row_acc.clear();
    row_acc.resize(a.rows, 0.0);
    for j in 0..a.cols {
        let (lo, hi) = (a.col_ptr[j], a.col_ptr[j + 1]);
        let mut total = 0.0;
        for p in lo..hi {
            total += a.vals[p];
        }
        if total == 0.0 {
            for p in lo..hi {
                row_acc[a.row_idx[p]] += a.vals[p];
            }
        } else {
            for p in lo..hi {
                row_acc[a.row_idx[p]] += a.vals[p] / total;
            }
        }
    }
    row_acc.iter().map(|&v| (rho * v - 1.0).powi(2)).sum()
}

/// Shared tail of the optimal-decode paths: the empty-A convention,
/// the 1_k rhs, the optional ρ·1_r warm start, and the LSQR solve —
/// on already-selected A, with every buffer caller-owned. Free-standing
/// (not a method) so `optimal_trial` can call it while the straggler
/// scratch is borrowed.
fn optimal_err_on_selected(
    a: &CscMatrix,
    ones: &mut Vec<f64>,
    x0_buf: &mut Vec<f64>,
    lsqr_ws: &mut LsqrWorkspace,
    opts: &LsqrOptions,
    warm: Option<f64>,
) -> f64 {
    if a.cols == 0 || a.nnz() == 0 {
        return a.rows as f64;
    }
    ones.clear();
    ones.resize(a.rows, 1.0);
    let x0: Option<&[f64]> = match warm {
        Some(rho) => {
            x0_buf.clear();
            x0_buf.resize(a.cols, rho);
            Some(x0_buf)
        }
        None => None,
    };
    let summary = lsqr_with(a, ones, opts, x0, lsqr_ws);
    summary.residual_norm * summary.residual_norm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::{GradientCode, Scheme};
    use crate::decode::{OneStepDecoder, OptimalDecoder};

    fn draw_g(scheme: Scheme, k: usize, s: usize, seed: u64) -> CscMatrix {
        scheme.build(k, k, s).assignment(&mut Rng::new(seed))
    }

    #[test]
    fn fused_matches_materialized_bit_for_bit() {
        let g = draw_g(Scheme::Bgc, 40, 5, 1);
        let mut ws = DecodeWorkspace::new();
        let mut rng = Rng::new(2);
        for _ in 0..25 {
            let idx = rng.sample_indices(40, 30);
            let fused = ws.err1_fused(&g, &idx, 0.25);
            let mat = ws.err1_materialized(&g, &idx, 0.25);
            assert_eq!(fused.to_bits(), mat.to_bits(), "{fused} vs {mat}");
        }
    }

    #[test]
    fn fused_matches_decoder_on_selected_submatrix() {
        let g = draw_g(Scheme::Frc, 20, 5, 3);
        let mut ws = DecodeWorkspace::new();
        let idx = vec![0, 3, 7, 7, 19]; // repeats allowed, like FRC dups
        let rho = 0.4;
        let via_decoder = OneStepDecoder::new(rho).err1(&g.select_columns(&idx));
        let fused = ws.err1_fused(&g, &idx, rho);
        assert_eq!(fused.to_bits(), via_decoder.to_bits());
    }

    #[test]
    fn optimal_err_matches_allocating_decoder() {
        let g = draw_g(Scheme::Bgc, 30, 4, 4);
        let mut ws = DecodeWorkspace::new();
        let mut rng = Rng::new(5);
        let opts = LsqrOptions::default();
        for _ in 0..10 {
            let idx = rng.sample_indices(30, 22);
            let reference = OptimalDecoder::new().err(&g.select_columns(&idx));
            let cold = ws.optimal_err(&g, &idx, &opts, None);
            assert_eq!(cold.to_bits(), reference.to_bits(), "{cold} vs {reference}");
        }
    }

    #[test]
    fn warm_start_agrees_with_cold_within_tolerance() {
        let g = draw_g(Scheme::Bgc, 30, 4, 6);
        let mut ws = DecodeWorkspace::new();
        let mut rng = Rng::new(7);
        let opts = LsqrOptions::default();
        let rho = 30.0 / (22.0 * 4.0);
        for _ in 0..10 {
            let idx = rng.sample_indices(30, 22);
            let cold = ws.optimal_err(&g, &idx, &opts, None);
            let warm = ws.optimal_err(&g, &idx, &opts, Some(rho));
            assert!(
                (warm - cold).abs() < 1e-6 * (1.0 + cold),
                "warm {warm} vs cold {cold}"
            );
        }
    }

    #[test]
    fn trial_methods_consume_rng_like_legacy_path() {
        // Same seed -> the trial methods and the historical allocating
        // sequence draw identical straggler sets and identical errors.
        let g = draw_g(Scheme::RegularGraph, 24, 4, 8);
        let (r, rho) = (18usize, 24.0 / (18.0 * 4.0));

        let mut legacy_rng = Rng::new(9);
        let idx = legacy_rng.sample_indices(24, r);
        let legacy = OneStepDecoder::new(rho).err1(&g.select_columns(&idx));

        let mut ws = DecodeWorkspace::new();
        let mut rng = Rng::new(9);
        let fused = ws.onestep_trial(&g, r, rho, &mut rng);
        assert_eq!(fused.to_bits(), legacy.to_bits());
        assert_eq!(ws.last_non_stragglers(), &idx[..]);
    }

    #[test]
    fn empty_selection_gives_err_k() {
        let g = draw_g(Scheme::Frc, 12, 3, 10);
        let mut ws = DecodeWorkspace::new();
        assert_eq!(ws.err1_fused(&g, &[], 1.0), 12.0);
        assert_eq!(ws.optimal_err(&g, &[], &LsqrOptions::default(), None), 12.0);
    }

    #[test]
    fn streamed_err1_matches_fused_bitwise_on_boolean_g() {
        let g = draw_g(Scheme::Bgc, 40, 5, 21);
        let mut ws = DecodeWorkspace::new();
        ws.mirror_csr(&g);
        let mut rng = Rng::new(22);
        for _ in 0..20 {
            let r = 1 + rng.usize(40);
            let idx = rng.sample_indices(40, r);
            let rho = 40.0 / (r as f64 * 5.0);
            let fused = ws.err1_fused(&g, &idx, rho);
            let streamed = ws.err1_streamed(&idx, rho);
            assert_eq!(fused.to_bits(), streamed.to_bits(), "r={r}: {fused} vs {streamed}");
        }
    }

    #[test]
    fn streamed_handles_repeated_columns_like_fused() {
        let g = draw_g(Scheme::Frc, 20, 5, 23);
        let mut ws = DecodeWorkspace::new();
        ws.mirror_csr(&g);
        let idx = vec![3, 3, 3, 7, 0];
        let fused = ws.err1_fused(&g, &idx, 0.4);
        let streamed = ws.err1_streamed(&idx, 0.4);
        assert_eq!(fused.to_bits(), streamed.to_bits());
    }

    #[test]
    fn streamed_trial_consumes_rng_like_fused_trial() {
        let g = draw_g(Scheme::RegularGraph, 24, 4, 24);
        let (r, rho) = (18usize, 24.0 / (18.0 * 4.0));
        let mut ws_a = DecodeWorkspace::new();
        let mut ws_b = DecodeWorkspace::new();
        ws_b.mirror_csr(&g);
        let mut rng_a = Rng::new(25);
        let mut rng_b = Rng::new(25);
        for _ in 0..10 {
            let fused = ws_a.onestep_trial(&g, r, rho, &mut rng_a);
            let streamed = ws_b.onestep_trial_streamed(r, rho, &mut rng_b);
            assert_eq!(fused.to_bits(), streamed.to_bits());
            assert_eq!(ws_a.last_non_stragglers(), ws_b.last_non_stragglers());
        }
        assert_eq!(rng_a.next_u64(), rng_b.next_u64());
    }

    #[test]
    #[should_panic(expected = "mirror_csr")]
    fn streamed_without_mirror_panics() {
        let mut ws = DecodeWorkspace::new();
        let mut rng = Rng::new(1);
        ws.onestep_trial_streamed(3, 1.0, &mut rng);
    }

    #[test]
    fn redraw_trials_match_legacy_sequence_bitwise() {
        for scheme in [Scheme::Bgc, Scheme::Rbgc, Scheme::RegularGraph, Scheme::Frc] {
            let (k, s, r) = (24usize, 4usize, 18usize);
            let rho = k as f64 / (r as f64 * s as f64);
            let code = scheme.build(k, k, s);
            let opts = LsqrOptions::default();

            let mut legacy_ws = DecodeWorkspace::new();
            let mut legacy_rng = Rng::new(26);
            let mut redraw_ws = DecodeWorkspace::new();
            let mut redraw_rng = Rng::new(26);
            for trial in 0..8 {
                let g = code.assignment(&mut legacy_rng);
                let legacy = legacy_ws.onestep_trial(&g, r, rho, &mut legacy_rng);
                let redrawn = redraw_ws.onestep_redraw_trial(code.as_ref(), r, rho, &mut redraw_rng);
                assert_eq!(legacy.to_bits(), redrawn.to_bits(), "{scheme:?} trial {trial}");

                let g2 = code.assignment(&mut legacy_rng);
                let legacy_opt = legacy_ws.optimal_trial(&g2, r, &opts, Some(rho), &mut legacy_rng);
                let redrawn_opt =
                    redraw_ws.optimal_redraw_trial(code.as_ref(), r, &opts, Some(rho), &mut redraw_rng);
                assert_eq!(legacy_opt.to_bits(), redrawn_opt.to_bits(), "{scheme:?} trial {trial}");
            }
            assert_eq!(legacy_rng.next_u64(), redraw_rng.next_u64(), "{scheme:?} rng diverged");
        }
    }

    #[test]
    fn normalized_redraw_trial_matches_legacy_sequence_bitwise() {
        use crate::codes::normalized::normalize_columns;
        let (k, s, r) = (24usize, 4usize, 18usize);
        let rho = k as f64 / r as f64;
        let code = Scheme::Bgc.build(k, k, s);
        let mut legacy_rng = Rng::new(33);
        let mut fused_rng = Rng::new(33);
        let mut ws = DecodeWorkspace::new();
        for trial in 0..10 {
            let g = code.assignment(&mut legacy_rng);
            let idx = legacy_rng.sample_indices(k, r);
            let legacy = OneStepDecoder::new(rho).err1(&normalize_columns(&g.select_columns(&idx)));
            let fused = ws.onestep_normalized_redraw_trial(code.as_ref(), r, rho, &mut fused_rng);
            assert_eq!(legacy.to_bits(), fused.to_bits(), "trial {trial}");
        }
        assert_eq!(legacy_rng.next_u64(), fused_rng.next_u64());
    }

    #[test]
    fn with_variants_under_uniform_model_match_r_based_methods_bitwise() {
        use crate::stragglers::UniformStragglers;
        let (k, s, delta) = (24usize, 4usize, 0.25);
        let model = UniformStragglers::new(delta);
        let r = model.r(k);
        let rho = k as f64 / (r as f64 * s as f64);
        let rho_norm = k as f64 / r as f64;
        let opts = LsqrOptions::default();
        for scheme in [Scheme::Bgc, Scheme::Frc] {
            let code = scheme.build(k, k, s);
            let mut ws_a = DecodeWorkspace::new();
            let mut ws_b = DecodeWorkspace::new();
            let mut rng_a = Rng::new(40);
            let mut rng_b = Rng::new(40);
            for trial in 0..6 {
                let legacy = ws_a.onestep_redraw_trial(code.as_ref(), r, rho, &mut rng_a);
                let spine = ws_b.onestep_redraw_trial_with(code.as_ref(), &model, rho, &mut rng_b);
                assert_eq!(legacy.to_bits(), spine.to_bits(), "{scheme:?} onestep {trial}");
                assert!(ws_b.last_gather_time().is_nan());

                let legacy =
                    ws_a.optimal_redraw_trial(code.as_ref(), r, &opts, Some(rho), &mut rng_a);
                let spine = ws_b
                    .optimal_redraw_trial_with(code.as_ref(), &model, &opts, Some(rho), &mut rng_b);
                assert_eq!(legacy.to_bits(), spine.to_bits(), "{scheme:?} optimal {trial}");

                let legacy =
                    ws_a.onestep_normalized_redraw_trial(code.as_ref(), r, rho_norm, &mut rng_a);
                let spine = ws_b.onestep_normalized_redraw_trial_with(
                    code.as_ref(),
                    &model,
                    rho_norm,
                    &mut rng_b,
                );
                assert_eq!(legacy.to_bits(), spine.to_bits(), "{scheme:?} normalized {trial}");

                let legacy = ws_a.redraw_submatrix(code.as_ref(), r, &mut rng_a).clone();
                let spine = ws_b.redraw_submatrix_with(code.as_ref(), &model, &mut rng_b);
                assert_eq!(*spine, legacy, "{scheme:?} submatrix {trial}");
            }
            assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "{scheme:?} rng diverged");
        }
    }

    #[test]
    fn latency_model_trials_record_gather_time() {
        use crate::stragglers::{DeadlinePolicy, LatencyModel, LatencyStragglers};
        let (k, s, r) = (20usize, 4usize, 15usize);
        let rho = k as f64 / (r as f64 * s as f64);
        let code = Scheme::Bgc.build(k, k, s);
        let model = LatencyStragglers {
            model: LatencyModel::Pareto { scale: 0.1, shape: 1.5 },
            policy: DeadlinePolicy::FastestR(r),
        };
        let mut ws = DecodeWorkspace::new();
        let mut rng = Rng::new(41);
        for _ in 0..5 {
            let err = ws.onestep_redraw_trial_with(code.as_ref(), &model, rho, &mut rng);
            assert!(err.is_finite() && err >= 0.0);
            assert_eq!(ws.last_non_stragglers().len(), r);
            // Pareto(0.1, ·) latencies are >= 0.1; the r-th order
            // statistic is a real gather time.
            assert!(ws.last_gather_time() >= 0.1);
        }
    }

    #[test]
    fn fixed_g_with_variants_match_fixed_g_r_based_methods() {
        use crate::stragglers::UniformStragglers;
        let (k, s, delta) = (30usize, 5usize, 0.3);
        let model = UniformStragglers::new(delta);
        let r = model.r(k);
        let rho = k as f64 / (r as f64 * s as f64);
        let g = draw_g(Scheme::Bgc, k, s, 42);
        let opts = LsqrOptions::default();
        let mut ws_a = DecodeWorkspace::new();
        let mut ws_b = DecodeWorkspace::new();
        let mut rng_a = Rng::new(43);
        let mut rng_b = Rng::new(43);
        for _ in 0..8 {
            let legacy = ws_a.onestep_trial(&g, r, rho, &mut rng_a);
            let spine = ws_b.onestep_trial_with(&g, &model, rho, &mut rng_b);
            assert_eq!(legacy.to_bits(), spine.to_bits());
            let legacy = ws_a.optimal_trial(&g, r, &opts, None, &mut rng_a);
            let spine = ws_b.optimal_trial_with(&g, &model, &opts, None, &mut rng_b);
            assert_eq!(legacy.to_bits(), spine.to_bits());
        }
        assert_eq!(rng_a.next_u64(), rng_b.next_u64());
    }

    #[test]
    fn incremental_redraw_trial_matches_batch_spine_bitwise() {
        use crate::stragglers::{
            DeadlinePolicy, LatencyModel, LatencyStragglers, StragglerModel, UniformStragglers,
        };
        let (k, s, r) = (24usize, 4usize, 18usize);
        let rho = k as f64 / (r as f64 * s as f64);
        let models: Vec<Box<dyn StragglerModel>> = vec![
            Box::new(UniformStragglers::new(0.25)),
            Box::new(LatencyStragglers {
                model: LatencyModel::Pareto { scale: 0.1, shape: 1.5 },
                policy: DeadlinePolicy::FastestR(r),
            }),
            Box::new(LatencyStragglers {
                model: LatencyModel::ShiftedExp { base: 0.1, rate: 2.0 },
                policy: DeadlinePolicy::Fixed(0.6),
            }),
        ];
        for scheme in [Scheme::Bgc, Scheme::Frc, Scheme::RegularGraph] {
            let code = scheme.build(k, k, s);
            for model in &models {
                let mut ws_a = DecodeWorkspace::new();
                let mut ws_b = DecodeWorkspace::new();
                let mut rng_a = Rng::new(50);
                let mut rng_b = Rng::new(50);
                for trial in 0..6 {
                    let batch =
                        ws_a.onestep_redraw_trial_with(code.as_ref(), model.as_ref(), rho, &mut rng_a);
                    let inc = ws_b.onestep_incremental_redraw_trial_with(
                        code.as_ref(),
                        model.as_ref(),
                        rho,
                        &mut rng_b,
                    );
                    assert_eq!(batch.to_bits(), inc.to_bits(), "{scheme:?} {} trial {trial}", model.name());
                    assert_eq!(
                        ws_a.last_gather_time().to_bits(),
                        ws_b.last_gather_time().to_bits()
                    );
                }
                assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "{scheme:?} rng diverged");
            }
        }
    }

    #[test]
    fn anytime_trial_without_rules_matches_plain_incremental_trial() {
        use crate::stragglers::{DeadlinePolicy, LatencyModel, LatencyStragglers};
        let (k, s, r) = (20usize, 4usize, 15usize);
        let rho = k as f64 / (r as f64 * s as f64);
        let code = Scheme::Bgc.build(k, k, s);
        let model = LatencyStragglers {
            model: LatencyModel::Pareto { scale: 0.1, shape: 1.5 },
            policy: DeadlinePolicy::FastestR(r),
        };
        let mut ws_a = DecodeWorkspace::new();
        let mut ws_b = DecodeWorkspace::new();
        let mut rng_a = Rng::new(51);
        let mut rng_b = Rng::new(51);
        for _ in 0..5 {
            let plain =
                ws_a.onestep_incremental_redraw_trial_with(code.as_ref(), &model, rho, &mut rng_a);
            let (gather, err1) = ws_b.onestep_incremental_anytime_redraw_trial_with(
                code.as_ref(),
                &model,
                rho,
                None,
                None,
                &mut rng_b,
            );
            assert_eq!(plain.to_bits(), err1.to_bits());
            assert_eq!(gather.to_bits(), ws_a.last_gather_time().to_bits());
        }
        assert_eq!(rng_a.next_u64(), rng_b.next_u64());
    }

    #[test]
    fn anytime_target_stops_at_first_satisfying_arrival() {
        use crate::stragglers::{DeadlinePolicy, LatencyModel, LatencyStragglers};
        let (k, s, r) = (20usize, 4usize, 18usize);
        let rho = k as f64 / (r as f64 * s as f64);
        let code = Scheme::Frc.build(k, k, s);
        let model = LatencyStragglers {
            model: LatencyModel::ShiftedExp { base: 0.1, rate: 2.0 },
            policy: DeadlinePolicy::FastestR(r),
        };
        let mut ws = DecodeWorkspace::new();
        // Stopping on a target can only shorten the gather, and when it
        // fires the exact err1 is at or below the target.
        let (gather_full, _) = ws.onestep_incremental_anytime_redraw_trial_with(
            code.as_ref(), &model, rho, None, None, &mut Rng::new(53),
        );
        let (gather_stop, err1) = ws.onestep_incremental_anytime_redraw_trial_with(
            code.as_ref(), &model, rho, Some(0.9), None, &mut Rng::new(53),
        );
        assert!(err1 <= 0.9 * k as f64 || gather_stop.to_bits() == gather_full.to_bits());
        assert!(gather_stop <= gather_full);
    }

    #[test]
    fn anytime_deadline_revision_only_shortens_the_gather() {
        use crate::stragglers::{DeadlinePolicy, LatencyModel, LatencyStragglers};
        let (k, s) = (20usize, 4usize);
        let rho = k as f64 / (15.0 * s as f64);
        let code = Scheme::Bgc.build(k, k, s);
        let model = LatencyStragglers {
            model: LatencyModel::Pareto { scale: 0.1, shape: 1.2 },
            policy: DeadlinePolicy::Fixed(5.0),
        };
        let mut ws = DecodeWorkspace::new();
        for seed in 60..65 {
            let (gather_full, err_full) = ws.onestep_incremental_anytime_redraw_trial_with(
                code.as_ref(), &model, rho, None, None, &mut Rng::new(seed),
            );
            // Revise at t=0.2 down to t=0.3: cutoff becomes 0.3.
            let (gather_rev, err_rev) = ws.onestep_incremental_anytime_redraw_trial_with(
                code.as_ref(), &model, rho, None, Some((0.2, 0.3)), &mut Rng::new(seed),
            );
            assert_eq!(gather_full, 5.0);
            assert_eq!(gather_rev, 0.3);
            assert!(err_rev.is_finite() && err_rev >= 0.0 && err_full >= 0.0);
            // Revision past the original deadline is a no-op.
            let (gather_noop, err_noop) = ws.onestep_incremental_anytime_redraw_trial_with(
                code.as_ref(), &model, rho, None, Some((6.0, 9.0)), &mut Rng::new(seed),
            );
            assert_eq!(gather_noop, 5.0);
            assert_eq!(err_noop.to_bits(), err_full.to_bits());
        }
    }

    #[test]
    fn prefix_trials_at_full_prefix_match_batch_trials_bitwise() {
        let (k, s, r) = (24usize, 4usize, 18usize);
        let rho = k as f64 / (r as f64 * s as f64);
        let g = draw_g(Scheme::Bgc, k, s, 54);
        let opts = LsqrOptions::default();
        let mut ws_a = DecodeWorkspace::new();
        let mut ws_b = DecodeWorkspace::new();
        let mut rng_a = Rng::new(55);
        let mut rng_b = Rng::new(55);
        for _ in 0..6 {
            let batch = ws_a.onestep_trial(&g, r, rho, &mut rng_a);
            let prefixed = ws_b.onestep_prefix_trial(&g, r, r, rho, &mut rng_b);
            assert_eq!(batch.to_bits(), prefixed.to_bits());
            let batch = ws_a.optimal_trial(&g, r, &opts, Some(rho), &mut rng_a);
            let prefixed = ws_b.optimal_prefix_trial(&g, r, r, &opts, Some(rho), &mut rng_b);
            assert_eq!(batch.to_bits(), prefixed.to_bits());
        }
        assert_eq!(rng_a.next_u64(), rng_b.next_u64());
    }

    #[test]
    fn prefix_trial_matches_manual_prefix_decode() {
        let (k, s, r, p) = (24usize, 4usize, 18usize, 7usize);
        let rho = k as f64 / (r as f64 * s as f64);
        let g = draw_g(Scheme::RegularGraph, k, s, 56);
        let mut ws = DecodeWorkspace::new();
        let mut rng = Rng::new(57);
        let prefixed = ws.onestep_prefix_trial(&g, r, p, rho, &mut rng);
        let drawn: Vec<usize> = ws.last_non_stragglers()[..p].to_vec();
        let batch = ws.err1_fused(&g, &drawn, rho);
        assert_eq!(prefixed.to_bits(), batch.to_bits());
    }

    #[test]
    fn adopt_arrival_prefix_rematerializes_sorted_prefix() {
        use crate::stragglers::{DeadlinePolicy, LatencyModel, LatencyStragglers};
        let (k, s, r) = (20usize, 4usize, 14usize);
        let g = draw_g(Scheme::Bgc, k, s, 58);
        let model = LatencyStragglers {
            model: LatencyModel::Pareto { scale: 0.1, shape: 1.5 },
            policy: DeadlinePolicy::FastestR(r),
        };
        let mut ws = DecodeWorkspace::new();
        let mut rng = Rng::new(59);
        ws.select_submatrix_with(&g, &model, &mut rng);
        let arrivals: Vec<usize> = ws.last_arrival_order().to_vec();
        let stop = 5usize;
        let gather = ws.last_latencies()[arrivals[stop - 1]];
        ws.adopt_arrival_prefix(&g, stop, gather);
        let mut expect = arrivals[..stop].to_vec();
        expect.sort_unstable();
        assert_eq!(ws.last_non_stragglers(), &expect[..]);
        assert_eq!(ws.last_gather_time().to_bits(), gather.to_bits());
        // The re-materialized A matches a direct selection.
        let direct = g.select_columns(&expect);
        let weights = vec![0.25; stop];
        let via_ws = ws.decode_error_selected(&weights);
        let reference = crate::decode::decode_error(&direct, &weights);
        assert_eq!(via_ws.to_bits(), reference.to_bits());
    }

    #[test]
    fn redraw_submatrix_matches_draw_non_straggler_matrix() {
        use crate::sim::figures::draw_non_straggler_matrix;
        let (k, s, r) = (20usize, 5usize, 14usize);
        let mut legacy_rng = Rng::new(27);
        let mut ws_rng = Rng::new(27);
        let mut ws = DecodeWorkspace::new();
        let code = Scheme::Bgc.build(k, k, s);
        for _ in 0..6 {
            let reference = draw_non_straggler_matrix(Scheme::Bgc, k, s, r, &mut legacy_rng);
            let a = ws.redraw_submatrix(code.as_ref(), r, &mut ws_rng);
            assert_eq!(*a, reference);
        }
        assert_eq!(legacy_rng.next_u64(), ws_rng.next_u64());
    }
}
