//! Workspace-reused decode engine — the zero-allocation trial pipeline.
//!
//! Every figure point in the paper averages over thousands of trials,
//! and each trial used to allocate the straggler index set, the
//! submatrix A (three fresh `Vec`s in `select_columns`), the row-sum
//! buffer, and all LSQR iteration vectors. A [`DecodeWorkspace`] owns
//! all of that scratch — one per worker thread, handed to the
//! Monte-Carlo engine via `MonteCarlo::mean_ws` — so the steady-state
//! trial loop performs **zero heap allocations** (pinned by the
//! `zero_alloc` integration test).
//!
//! The centerpiece is the fused path [`err1_from_supports`]: the
//! paper's own §2.2 observation that one-step decoding is *streamable*
//! means `err_1(A) = ||ρ A 1_r − 1_k||²` needs only the row coverage
//! counts, which can be accumulated straight from G's columns — A is
//! never materialized. The accumulation visits the selected columns in
//! order, exactly like `select_columns` + `row_sums` would, so the
//! fused and materialized paths are bit-identical (pinned by the
//! `decode_parity` integration test).

use crate::linalg::{lsqr_with, CscMatrix, LsqrOptions, LsqrWorkspace};
use crate::util::Rng;

/// err_1(A) computed directly from G plus the non-straggler index set,
/// in O(k + nnz(A)), without materializing A. `row_acc` is the reused
/// coverage buffer (resized to `g.rows`, capacity kept).
///
/// Accumulation order matches `select_columns(ns)` + `row_sums()`
/// exactly, so results are bit-identical to the materialized path.
pub fn err1_from_supports(
    g: &CscMatrix,
    non_stragglers: &[usize],
    rho: f64,
    row_acc: &mut Vec<f64>,
) -> f64 {
    row_acc.clear();
    row_acc.resize(g.rows, 0.0);
    for &j in non_stragglers {
        assert!(j < g.cols, "column {j} out of bounds ({})", g.cols);
        for p in g.col_ptr[j]..g.col_ptr[j + 1] {
            row_acc[g.row_idx[p]] += g.vals[p];
        }
    }
    row_acc.iter().map(|&v| (rho * v - 1.0).powi(2)).sum()
}

/// Per-thread scratch for the straggler→decode trial pipeline.
///
/// All buffers grow to the largest instance seen and are then reused;
/// after a warmup trial, running more trials of the same shape does no
/// heap allocation at all.
#[derive(Clone, Debug)]
pub struct DecodeWorkspace {
    /// Materialized submatrix A (only the optimal path needs it).
    a: CscMatrix,
    /// Row coverage / row-sum accumulator (length k).
    row_acc: Vec<f64>,
    /// RHS ones vector 1_k for LSQR.
    ones: Vec<f64>,
    /// Warm-start vector (ρ · 1_r) for the optimal decoder.
    x0: Vec<f64>,
    /// Fisher-Yates scratch for straggler sampling (length n).
    pool: Vec<usize>,
    /// The sampled non-straggler index set (length r).
    idx: Vec<usize>,
    /// LSQR iteration vectors.
    lsqr: LsqrWorkspace,
}

impl Default for DecodeWorkspace {
    fn default() -> Self {
        Self::new()
    }
}

impl DecodeWorkspace {
    pub fn new() -> Self {
        DecodeWorkspace {
            a: CscMatrix::empty(),
            row_acc: Vec::new(),
            ones: Vec::new(),
            x0: Vec::new(),
            pool: Vec::new(),
            idx: Vec::new(),
            lsqr: LsqrWorkspace::new(),
        }
    }

    /// The non-straggler set sampled by the most recent `*_trial` call.
    pub fn last_non_stragglers(&self) -> &[usize] {
        &self.idx
    }

    /// Fused one-step error for an explicit non-straggler set.
    pub fn err1_fused(&mut self, g: &CscMatrix, non_stragglers: &[usize], rho: f64) -> f64 {
        err1_from_supports(g, non_stragglers, rho, &mut self.row_acc)
    }

    /// Reference parity path: materialize A into the workspace
    /// submatrix, then run the row-sum pass (same result as
    /// [`DecodeWorkspace::err1_fused`], bit for bit).
    pub fn err1_materialized(&mut self, g: &CscMatrix, non_stragglers: &[usize], rho: f64) -> f64 {
        g.select_columns_into(non_stragglers, &mut self.a);
        self.a.row_sums_into(&mut self.row_acc);
        self.row_acc.iter().map(|&v| (rho * v - 1.0).powi(2)).sum()
    }

    /// Optimal decoding error err(A) for an explicit non-straggler set,
    /// via workspace-owned LSQR. `warm = Some(rho)` warm-starts at the
    /// one-step weights ρ·1_r (deterministic per figure point, so trial
    /// results stay independent of thread scheduling); `None` is
    /// bit-identical to `OptimalDecoder::err` on the materialized A.
    pub fn optimal_err(
        &mut self,
        g: &CscMatrix,
        non_stragglers: &[usize],
        opts: &LsqrOptions,
        warm: Option<f64>,
    ) -> f64 {
        g.select_columns_into(non_stragglers, &mut self.a);
        optimal_err_on_selected(&self.a, &mut self.ones, &mut self.x0, &mut self.lsqr, opts, warm)
    }

    /// One full Monte-Carlo trial of the one-step decoder: sample r
    /// uniform non-stragglers from G's columns, then compute err_1
    /// through the fused no-materialize path. Allocation-free at steady
    /// state. RNG consumption matches the historical
    /// `sample_indices` + `select_columns` + `err1` sequence, so seeded
    /// results are unchanged.
    pub fn onestep_trial(&mut self, g: &CscMatrix, r: usize, rho: f64, rng: &mut Rng) -> f64 {
        rng.sample_indices_into(g.cols, r, &mut self.pool, &mut self.idx);
        err1_from_supports(g, &self.idx, rho, &mut self.row_acc)
    }

    /// One full Monte-Carlo trial of the optimal decoder: sample r
    /// uniform non-stragglers, materialize A into the reused buffer,
    /// solve with workspace LSQR. See [`DecodeWorkspace::optimal_err`]
    /// for the `warm` semantics.
    pub fn optimal_trial(
        &mut self,
        g: &CscMatrix,
        r: usize,
        opts: &LsqrOptions,
        warm: Option<f64>,
        rng: &mut Rng,
    ) -> f64 {
        rng.sample_indices_into(g.cols, r, &mut self.pool, &mut self.idx);
        g.select_columns_into(&self.idx, &mut self.a);
        optimal_err_on_selected(&self.a, &mut self.ones, &mut self.x0, &mut self.lsqr, opts, warm)
    }
}

/// Shared tail of the optimal-decode paths: the empty-A convention,
/// the 1_k rhs, the optional ρ·1_r warm start, and the LSQR solve —
/// on already-selected A, with every buffer caller-owned. Free-standing
/// (not a method) so `optimal_trial` can call it while `self.idx` is
/// borrowed.
fn optimal_err_on_selected(
    a: &CscMatrix,
    ones: &mut Vec<f64>,
    x0_buf: &mut Vec<f64>,
    lsqr_ws: &mut LsqrWorkspace,
    opts: &LsqrOptions,
    warm: Option<f64>,
) -> f64 {
    if a.cols == 0 || a.nnz() == 0 {
        return a.rows as f64;
    }
    ones.clear();
    ones.resize(a.rows, 1.0);
    let x0: Option<&[f64]> = match warm {
        Some(rho) => {
            x0_buf.clear();
            x0_buf.resize(a.cols, rho);
            Some(x0_buf)
        }
        None => None,
    };
    let summary = lsqr_with(a, ones, opts, x0, lsqr_ws);
    summary.residual_norm * summary.residual_norm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::{GradientCode, Scheme};
    use crate::decode::{OneStepDecoder, OptimalDecoder};

    fn draw_g(scheme: Scheme, k: usize, s: usize, seed: u64) -> CscMatrix {
        scheme.build(k, k, s).assignment(&mut Rng::new(seed))
    }

    #[test]
    fn fused_matches_materialized_bit_for_bit() {
        let g = draw_g(Scheme::Bgc, 40, 5, 1);
        let mut ws = DecodeWorkspace::new();
        let mut rng = Rng::new(2);
        for _ in 0..25 {
            let idx = rng.sample_indices(40, 30);
            let fused = ws.err1_fused(&g, &idx, 0.25);
            let mat = ws.err1_materialized(&g, &idx, 0.25);
            assert_eq!(fused.to_bits(), mat.to_bits(), "{fused} vs {mat}");
        }
    }

    #[test]
    fn fused_matches_decoder_on_selected_submatrix() {
        let g = draw_g(Scheme::Frc, 20, 5, 3);
        let mut ws = DecodeWorkspace::new();
        let idx = vec![0, 3, 7, 7, 19]; // repeats allowed, like FRC dups
        let rho = 0.4;
        let via_decoder = OneStepDecoder::new(rho).err1(&g.select_columns(&idx));
        let fused = ws.err1_fused(&g, &idx, rho);
        assert_eq!(fused.to_bits(), via_decoder.to_bits());
    }

    #[test]
    fn optimal_err_matches_allocating_decoder() {
        let g = draw_g(Scheme::Bgc, 30, 4, 4);
        let mut ws = DecodeWorkspace::new();
        let mut rng = Rng::new(5);
        let opts = LsqrOptions::default();
        for _ in 0..10 {
            let idx = rng.sample_indices(30, 22);
            let reference = OptimalDecoder::new().err(&g.select_columns(&idx));
            let cold = ws.optimal_err(&g, &idx, &opts, None);
            assert_eq!(cold.to_bits(), reference.to_bits(), "{cold} vs {reference}");
        }
    }

    #[test]
    fn warm_start_agrees_with_cold_within_tolerance() {
        let g = draw_g(Scheme::Bgc, 30, 4, 6);
        let mut ws = DecodeWorkspace::new();
        let mut rng = Rng::new(7);
        let opts = LsqrOptions::default();
        let rho = 30.0 / (22.0 * 4.0);
        for _ in 0..10 {
            let idx = rng.sample_indices(30, 22);
            let cold = ws.optimal_err(&g, &idx, &opts, None);
            let warm = ws.optimal_err(&g, &idx, &opts, Some(rho));
            assert!(
                (warm - cold).abs() < 1e-6 * (1.0 + cold),
                "warm {warm} vs cold {cold}"
            );
        }
    }

    #[test]
    fn trial_methods_consume_rng_like_legacy_path() {
        // Same seed -> the trial methods and the historical allocating
        // sequence draw identical straggler sets and identical errors.
        let g = draw_g(Scheme::RegularGraph, 24, 4, 8);
        let (r, rho) = (18usize, 24.0 / (18.0 * 4.0));

        let mut legacy_rng = Rng::new(9);
        let idx = legacy_rng.sample_indices(24, r);
        let legacy = OneStepDecoder::new(rho).err1(&g.select_columns(&idx));

        let mut ws = DecodeWorkspace::new();
        let mut rng = Rng::new(9);
        let fused = ws.onestep_trial(&g, r, rho, &mut rng);
        assert_eq!(fused.to_bits(), legacy.to_bits());
        assert_eq!(ws.last_non_stragglers(), &idx[..]);
    }

    #[test]
    fn empty_selection_gives_err_k() {
        let g = draw_g(Scheme::Frc, 12, 3, 10);
        let mut ws = DecodeWorkspace::new();
        assert_eq!(ws.err1_fused(&g, &[], 1.0), 12.0);
        assert_eq!(ws.optimal_err(&g, &[], &LsqrOptions::default(), None), 12.0);
    }
}
