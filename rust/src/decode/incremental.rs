//! Incremental anytime decoding — per-survivor state updates with a
//! prefix-parity contract.
//!
//! The paper's §2.2 observation is that one-step decoding is
//! *streamable*: `err_1(A) = ||ρ A 1_r − 1_k||²` depends on the
//! survivor submatrix A only through its row coverage counts, and each
//! arriving survivor column touches exactly its own support. The
//! retired `StreamingOneStep` reference module (folded into this one)
//! demonstrated the consequence: a master can ingest coded messages
//! one at a time in O(deg) work and O(k) memory — independent of how
//! many columns stream past — maintain a running error signal, and
//! stop early the moment every partition is covered (for FRC, the
//! first moment `err_1` can reach zero). [`IncrementalDecoder`] is the
//! production form of that idea, owned by
//! [`crate::decode::DecodeWorkspace`] and threaded through the
//! coordinator, the scenario sweeps, and the serve daemon.
//!
//! ## The prefix-parity contract
//!
//! After the first i arrivals, the incremental state must be
//! **bit-identical** to a batch decode
//! ([`crate::decode::err1_from_supports`]) on exactly those i
//! survivors — for every prefix i, every code scheme, every straggler
//! model (pinned by `tests/incremental_parity.rs`). Two facts make
//! this achievable without re-scanning prior survivors:
//!
//! 1. **Coverage is exact.** Every code the paper constructs is
//!    boolean, so row coverage counts are small integers accumulated
//!    in f64 — every add is exact, which makes the accumulated
//!    coverage independent of arrival order *at the bit level*. The
//!    incremental scatter therefore lands on the same `row_acc` bits
//!    as the batch path no matter how the survivor set is permuted.
//! 2. **The exact query re-folds, never delta-updates.** The err₁
//!    *total* is a sum of per-row terms `(ρ·cov − 1)²`; updating it by
//!    subtracting old terms and adding new ones re-associates the
//!    floating-point sum and drifts from the batch bits. So
//!    [`IncrementalDecoder::err1`] is an O(k) row-order fold over the
//!    coverage buffer — the *same* fold `err1_from_supports` ends
//!    with — and the O(deg) delta-updated running total is exposed
//!    separately as an estimate-grade hint
//!    ([`IncrementalDecoder::err1_running`]).
//!
//! Per-arrival work is O(deg): one walk down the arriving column of
//! the CSC assignment matrix. (The workspace's CSR mirror is the
//! right layout for *batch* row sweeps; an arrival is a single
//! column, which CSC hands us contiguously.)
//!
//! ## Arrival order is contract
//!
//! Which survivor arrives "next" is defined by the straggler model
//! ([`crate::stragglers::StragglerScratch::compute_arrivals`]):
//! latency models order by ascending (latency, worker index); models
//! with no time axis (uniform, adversarial) arrive in draw order.
//! Everything downstream — the coordinator's err₁ trace, the anytime
//! stopping rules, the serve `prefix` decode — inherits that order.
//!
//! ## The warm-start rule
//!
//! For the survivor-set-optimal decoder (Glasgow–Wootters arm),
//! arrivals only ever *append* columns to the prefix submatrix, so the
//! LSQR solution for the previous prefix is a valid partial solution
//! for the next one: [`IncrementalDecoder::optimal_err`] starts from
//! the previous prefix's solution extended with the one-step weight ρ
//! for each newly arrived column (and from ρ·1 on the first solve —
//! bit-identical to the batch `warm = Some(rho)` path). "Decode at
//! deadline" is then ~zero marginal work: the final solve starts
//! within a few correction iterations of the answer. Warm and cold
//! solves agree in `residual_norm` to solver tolerance (pinned at the
//! final prefix by the parity suite), not bit-for-bit — which is why
//! the one-step arm, not LSQR, carries the bitwise contract.

use crate::linalg::{lsqr_with, CscMatrix, LsqrOptions, LsqrSummary, LsqrWorkspace};

/// Streaming one-step + optimal decode state over an arrival-ordered
/// survivor prefix. See the module docs for the three contracts
/// (prefix parity, arrival order, warm start).
#[derive(Clone, Debug)]
pub struct IncrementalDecoder {
    /// Row count of the assignment matrix this round decodes against.
    k: usize,
    /// One-step step size ρ = k/(r·s) for the *planned* r (a streaming
    /// master cannot know the realized survivor count in advance).
    rho: f64,
    /// Exact row coverage counts — integer-valued for boolean G, so
    /// bit-identical to the batch accumulation in any arrival order.
    row_acc: Vec<f64>,
    /// Survivor column indices in arrival order.
    arrived: Vec<usize>,
    /// O(deg)-delta-updated running err₁ — an estimate-grade hint (fp
    /// reassociation drifts from the batch bits); the exact query is
    /// [`IncrementalDecoder::err1`].
    err1_running: f64,
    /// Previous prefix's LSQR solution (length = arrivals at the time
    /// of the last solve) — the warm-start seed.
    x_prev: Vec<f64>,
    /// Materialized prefix submatrix for the optimal arm.
    a: CscMatrix,
    /// RHS ones vector 1_k for LSQR.
    ones: Vec<f64>,
    /// Warm-start assembly buffer (x_prev extended with ρ fill).
    x0: Vec<f64>,
    /// LSQR iteration vectors for the optimal arm.
    lsqr: LsqrWorkspace,
    /// Summary of the most recent optimal solve this round.
    last_summary: Option<LsqrSummary>,
}

impl Default for IncrementalDecoder {
    fn default() -> Self {
        Self::new()
    }
}

impl IncrementalDecoder {
    pub fn new() -> Self {
        IncrementalDecoder {
            k: 0,
            rho: 0.0,
            row_acc: Vec::new(),
            arrived: Vec::new(),
            err1_running: 0.0,
            x_prev: Vec::new(),
            a: CscMatrix::empty(),
            ones: Vec::new(),
            x0: Vec::new(),
            lsqr: LsqrWorkspace::new(),
            last_summary: None,
        }
    }

    /// Pre-size the one-step arrival buffers for rounds of up to
    /// (k, n) so the steady-state arrival loop performs zero heap
    /// allocations from the first arrival (`tests/zero_alloc.rs`).
    /// The optimal arm's submatrix and LSQR vectors still size
    /// themselves on the first solve (warmup regime) — reserving the
    /// hard k·n nnz bound here would double the workspace footprint
    /// for a path many rounds never take.
    pub fn reserve(&mut self, k: usize, n: usize) {
        self.row_acc.reserve(k);
        self.arrived.reserve(n);
        self.ones.reserve(k);
        self.x0.reserve(n);
        self.x_prev.reserve(n);
        self.a.col_ptr.reserve(n + 1);
    }

    /// Start a fresh round against a k-row assignment matrix at step
    /// size ρ. The empty prefix decodes to err₁ = k exactly (every
    /// row term is (ρ·0 − 1)² = 1).
    pub fn begin(&mut self, k: usize, rho: f64) {
        self.k = k;
        self.rho = rho;
        self.row_acc.clear();
        self.row_acc.resize(k, 0.0);
        self.arrived.clear();
        self.err1_running = k as f64;
        self.x_prev.clear();
        self.last_summary = None;
    }

    /// Ingest survivor column j of `g`: O(deg_j) — one walk down the
    /// arriving CSC column, never re-scanning prior survivors. Updates
    /// the exact coverage counts and the running err₁ hint.
    pub fn arrive(&mut self, g: &CscMatrix, j: usize) {
        assert_eq!(g.rows, self.k, "assignment row count changed mid-round");
        assert!(j < g.cols, "column {j} out of bounds ({})", g.cols);
        for p in g.col_ptr[j]..g.col_ptr[j + 1] {
            let i = g.row_idx[p];
            let old = self.row_acc[i];
            let new = old + g.vals[p];
            self.row_acc[i] = new;
            self.err1_running +=
                (self.rho * new - 1.0).powi(2) - (self.rho * old - 1.0).powi(2);
        }
        self.arrived.push(j);
    }

    /// The survivor prefix seen so far, in arrival order.
    pub fn arrived(&self) -> &[usize] {
        &self.arrived
    }

    /// Number of arrivals ingested this round.
    pub fn len(&self) -> usize {
        self.arrived.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrived.is_empty()
    }

    /// The step size ρ this round was begun with.
    pub fn rho(&self) -> f64 {
        self.rho
    }

    /// The exact coverage counts for the current prefix — bit-identical
    /// to the batch `row_acc` on the same survivors.
    pub fn coverage(&self) -> &[f64] {
        &self.row_acc
    }

    /// **Exact** err₁ of the current prefix: the O(k) row-order fold
    /// `Σ_i (ρ·cov_i − 1)²` — the same final fold as
    /// [`crate::decode::err1_from_supports`], hence bit-identical to a
    /// batch decode on exactly the arrived survivors.
    pub fn err1(&self) -> f64 {
        let rho = self.rho;
        self.row_acc.iter().map(|&v| (rho * v - 1.0).powi(2)).sum()
    }

    /// The O(1)-query running err₁ maintained by per-arrival deltas.
    /// Estimate-grade: floating-point reassociation lets it drift a
    /// few ulp from [`IncrementalDecoder::err1`]; use it for cheap
    /// progress signals, the exact fold for decisions and outputs.
    pub fn err1_running(&self) -> f64 {
        self.err1_running
    }

    /// Survivor-set-optimal decode error err(A_prefix) = min_x
    /// ||A_prefix·x − 1_k||², LSQR warm-started per the module's
    /// warm-start rule. The first solve of a round starts from ρ·1
    /// and is bit-identical to the batch
    /// `DecodeWorkspace::optimal_err(g, prefix, opts, Some(rho))`;
    /// later solves start from the previous prefix's solution
    /// extended with ρ for each column that arrived since.
    pub fn optimal_err(&mut self, g: &CscMatrix, opts: &LsqrOptions) -> f64 {
        g.select_columns_into(&self.arrived, &mut self.a);
        if self.a.cols == 0 || self.a.nnz() == 0 {
            // Batch convention for a vacuous solve (optimal_err_on_selected).
            self.x_prev.clear();
            self.x_prev.resize(self.a.cols, self.rho);
            self.last_summary = None;
            return self.a.rows as f64;
        }
        self.ones.clear();
        self.ones.resize(self.a.rows, 1.0);
        self.x0.clear();
        self.x0.extend_from_slice(&self.x_prev);
        debug_assert!(self.x0.len() <= self.a.cols, "arrivals only append");
        self.x0.resize(self.a.cols, self.rho);
        let summary = lsqr_with(&self.a, &self.ones, opts, Some(&self.x0), &mut self.lsqr);
        self.x_prev.clear();
        self.x_prev.extend_from_slice(self.lsqr.x());
        self.last_summary = Some(summary);
        summary.residual_norm * summary.residual_norm
    }

    /// The optimal weights from the most recent
    /// [`IncrementalDecoder::optimal_err`] solve this round (empty
    /// before the first solve).
    pub fn optimal_weights(&self) -> &[f64] {
        &self.x_prev
    }

    /// Summary of the most recent optimal solve this round, for
    /// warm-vs-cold convergence comparisons.
    pub fn last_lsqr_summary(&self) -> Option<LsqrSummary> {
        self.last_summary
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::Scheme;
    use crate::decode::{err1_from_supports, DecodeWorkspace};
    use crate::util::Rng;

    fn draw_g(scheme: Scheme, k: usize, s: usize, seed: u64) -> CscMatrix {
        scheme.build(k, k, s).assignment(&mut Rng::new(seed))
    }

    #[test]
    fn every_prefix_matches_batch_bitwise() {
        let (k, s, r) = (24usize, 4usize, 18usize);
        let rho = k as f64 / (r as f64 * s as f64);
        let g = draw_g(Scheme::Bgc, k, s, 11);
        let arrivals = Rng::new(12).sample_indices(k, r);
        let mut inc = IncrementalDecoder::new();
        inc.begin(k, rho);
        let mut batch_acc = Vec::new();
        for i in 0..=r {
            if i > 0 {
                inc.arrive(&g, arrivals[i - 1]);
            }
            let batch = err1_from_supports(&g, &arrivals[..i], rho, &mut batch_acc);
            assert_eq!(inc.err1().to_bits(), batch.to_bits(), "prefix {i}");
            assert_eq!(inc.coverage(), &batch_acc[..], "prefix {i} coverage");
        }
    }

    #[test]
    fn empty_prefix_decodes_to_k_exactly() {
        let mut inc = IncrementalDecoder::new();
        inc.begin(17, 0.3);
        assert_eq!(inc.err1(), 17.0);
        assert_eq!(inc.err1_running(), 17.0);
        assert!(inc.is_empty());
    }

    #[test]
    fn coverage_bits_are_arrival_order_invariant_for_boolean_g() {
        let (k, s, r) = (30usize, 5usize, 21usize);
        let rho = k as f64 / (r as f64 * s as f64);
        let g = draw_g(Scheme::RegularGraph, k, s, 13);
        let fwd = Rng::new(14).sample_indices(k, r);
        let mut rev = fwd.clone();
        rev.reverse();
        let mut a = IncrementalDecoder::new();
        let mut b = IncrementalDecoder::new();
        a.begin(k, rho);
        b.begin(k, rho);
        for i in 0..r {
            a.arrive(&g, fwd[i]);
            b.arrive(&g, rev[i]);
        }
        assert_eq!(a.coverage(), b.coverage());
        assert_eq!(a.err1().to_bits(), b.err1().to_bits());
    }

    #[test]
    fn running_err1_tracks_exact_fold_closely() {
        let (k, s, r) = (40usize, 5usize, 30usize);
        let rho = k as f64 / (r as f64 * s as f64);
        let g = draw_g(Scheme::Frc, k, s, 15);
        let arrivals = Rng::new(16).sample_indices(k, r);
        let mut inc = IncrementalDecoder::new();
        inc.begin(k, rho);
        for &j in &arrivals {
            inc.arrive(&g, j);
            let exact = inc.err1();
            assert!(
                (inc.err1_running() - exact).abs() <= 1e-9 * (1.0 + exact),
                "hint {} vs exact {exact}",
                inc.err1_running()
            );
        }
    }

    #[test]
    fn first_optimal_solve_matches_batch_warm_path_bitwise() {
        let (k, s, r) = (24usize, 4usize, 18usize);
        let rho = k as f64 / (r as f64 * s as f64);
        let g = draw_g(Scheme::Bgc, k, s, 17);
        let arrivals = Rng::new(18).sample_indices(k, r);
        let opts = LsqrOptions::default();
        let mut ws = DecodeWorkspace::new();
        for i in [1usize, r / 2, r] {
            let mut inc = IncrementalDecoder::new();
            inc.begin(k, rho);
            for &j in &arrivals[..i] {
                inc.arrive(&g, j);
            }
            let streamed = inc.optimal_err(&g, &opts);
            let batch = ws.optimal_err(&g, &arrivals[..i], &opts, Some(rho));
            assert_eq!(streamed.to_bits(), batch.to_bits(), "prefix {i}");
        }
    }

    #[test]
    fn warm_start_across_prefixes_agrees_with_cold_at_final_prefix() {
        let (k, s, r) = (30usize, 4usize, 24usize);
        let rho = k as f64 / (r as f64 * s as f64);
        let g = draw_g(Scheme::Bgc, k, s, 19);
        let arrivals = Rng::new(20).sample_indices(k, r);
        let opts = LsqrOptions::default();
        let mut inc = IncrementalDecoder::new();
        inc.begin(k, rho);
        let mut warm = f64::NAN;
        for &j in &arrivals {
            inc.arrive(&g, j);
            warm = inc.optimal_err(&g, &opts);
        }
        let warm_summary = inc.last_lsqr_summary().expect("solved at final prefix");
        let mut ws = DecodeWorkspace::new();
        let cold = ws.optimal_err(&g, &arrivals, &opts, None);
        assert!(
            (warm - cold).abs() < 1e-6 * (1.0 + cold),
            "warm {warm} vs cold {cold}"
        );
        // Warm starts can only help: the correction solve starts near
        // the answer, so it must not run longer than the cold solve
        // plus the solver's own restart slack.
        assert!(warm_summary.converged || warm_summary.iterations > 0);
    }

    #[test]
    fn vacuous_prefix_optimal_is_k() {
        let g = draw_g(Scheme::Bgc, 12, 3, 21);
        let mut inc = IncrementalDecoder::new();
        inc.begin(12, 1.0);
        assert_eq!(inc.optimal_err(&g, &LsqrOptions::default()), 12.0);
    }

    #[test]
    fn frc_full_coverage_reaches_zero_err1() {
        // The retired StreamingOneStep demo: once every partition is
        // covered exactly 1/rho times, FRC's err1 hits zero — the
        // early-stop signal a streaming master can act on.
        let k = 12usize;
        let g = draw_g(Scheme::Frc, k, 3, 22);
        let mut inc = IncrementalDecoder::new();
        inc.begin(k, 1.0);
        for j in 0..k {
            inc.arrive(&g, j);
        }
        // FRC replicates each partition across its group; with every
        // column present each row is covered `s` times at rho = 1/s...
        // use the exact fold against the batch reference instead of a
        // closed form to stay scheme-agnostic.
        let all: Vec<usize> = (0..k).collect();
        let mut acc = Vec::new();
        let batch = err1_from_supports(&g, &all, 1.0, &mut acc);
        assert_eq!(inc.err1().to_bits(), batch.to_bits());
    }

    #[test]
    fn memory_is_independent_of_arrivals_after_reserve() {
        let (k, s) = (16usize, 3usize);
        let g = draw_g(Scheme::Cyclic, k, s, 23);
        let mut inc = IncrementalDecoder::new();
        inc.reserve(k, k);
        inc.begin(k, 0.5);
        let cap_before = inc.row_acc.capacity();
        for j in 0..k {
            inc.arrive(&g, j);
        }
        assert_eq!(inc.row_acc.capacity(), cap_before);
        assert_eq!(inc.len(), k);
    }
}
