//! Algorithmic decoding (paper Lemma 12 / §6.2, adapted from randomized
//! Kaczmarz [26]): u_t = (I - A A^T / ν)^t 1_k.
//!
//! ||u_t||^2 decreases monotonically to err(A) when ν >= ||A||_2^2; the
//! intermediate iterates interpolate between the one-step error (t = 1,
//! ν = rs^2/k — Lemma 17) and the optimal error (t -> ∞). Figure 5 plots
//! exactly these curves with ν = ||A||_2^2.

use super::Decoder;
use crate::linalg::{norm2_sq, spectral_norm, CscMatrix};
use crate::util::Rng;

/// How to pick ν.
#[derive(Clone, Copy, Debug)]
pub enum StepSize {
    /// ν = ||A||_2^2 estimated by power iteration (Fig. 5 setting).
    SpectralNormSq,
    /// ν = r s^2 / k (Lemma 17's closed-form choice).
    Lemma17 { k: usize, r: usize, s: usize },
    /// Explicit ν.
    Fixed(f64),
}

impl StepSize {
    pub fn resolve(&self, a: &CscMatrix, rng: &mut Rng) -> f64 {
        match *self {
            StepSize::SpectralNormSq => {
                let n = spectral_norm(a, rng, 300, 1e-10);
                // Tiny inflation keeps ν >= ||A||^2 despite estimation
                // error, preserving Lemma 12's monotonicity guarantee.
                (n * n * (1.0 + 1e-6)).max(f64::MIN_POSITIVE)
            }
            StepSize::Lemma17 { k, r, s } => r as f64 * (s * s) as f64 / k as f64,
            StepSize::Fixed(v) => v,
        }
    }
}

#[derive(Clone, Debug)]
pub struct AlgorithmicDecoder {
    pub steps: usize,
    pub step_size: StepSize,
    /// Seed for the power-iteration RNG (kept internal so the decoder is
    /// deterministic given A).
    pub seed: u64,
}

impl AlgorithmicDecoder {
    pub fn new(steps: usize, step_size: StepSize) -> Self {
        AlgorithmicDecoder { steps, step_size, seed: 0x5EED }
    }

    /// The iterate u_t after `steps` applications.
    pub fn iterate(&self, a: &CscMatrix) -> Vec<f64> {
        let mut rng = Rng::new(self.seed);
        let nu = self.step_size.resolve(a, &mut rng);
        let mut u = vec![1.0; a.rows];
        for _ in 0..self.steps {
            let atu = a.t_matvec(&u);
            let aatu = a.matvec(&atu);
            for (ui, yi) in u.iter_mut().zip(&aatu) {
                *ui -= yi / nu;
            }
        }
        u
    }

    /// ||u_t||^2 — the algorithmic decoding error at t = steps.
    pub fn error_at(&self, a: &CscMatrix) -> f64 {
        norm2_sq(&self.iterate(a))
    }
}

/// The whole curve {||u_t||^2}_{t=0..=t_max} in one sweep (Fig. 5's
/// series), reusing iterates instead of recomputing per t.
pub fn algorithmic_error_curve(
    a: &CscMatrix,
    step_size: StepSize,
    t_max: usize,
    rng: &mut Rng,
) -> Vec<f64> {
    let nu = step_size.resolve(a, rng);
    let mut u = vec![1.0; a.rows];
    let mut curve = Vec::with_capacity(t_max + 1);
    curve.push(norm2_sq(&u));
    // Scratch buffers reused across iterations (allocation-free loop).
    let mut atu = vec![0.0; a.cols];
    let mut aatu = vec![0.0; a.rows];
    for _ in 1..=t_max {
        a.t_matvec_into(&u, &mut atu);
        a.matvec_into(&atu, &mut aatu);
        for (ui, yi) in u.iter_mut().zip(&aatu) {
            *ui -= yi / nu;
        }
        curve.push(norm2_sq(&u));
    }
    curve
}

impl Decoder for AlgorithmicDecoder {
    /// Weights x such that A x = 1_k - u_t. From the recursion,
    /// `x = (1/ν) Σ_{i<t} Aᵀ u_i`; we accumulate it alongside u.
    fn weights(&self, a: &CscMatrix) -> Vec<f64> {
        let mut rng = Rng::new(self.seed);
        let nu = self.step_size.resolve(a, &mut rng);
        let mut u = vec![1.0; a.rows];
        let mut x = vec![0.0; a.cols];
        for _ in 0..self.steps {
            let atu = a.t_matvec(&u);
            for (xj, aj) in x.iter_mut().zip(&atu) {
                *xj += aj / nu;
            }
            let aatu = a.matvec(&atu);
            for (ui, yi) in u.iter_mut().zip(&aatu) {
                *ui -= yi / nu;
            }
        }
        x
    }

    fn name(&self) -> &'static str {
        "algorithmic"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::{BernoulliCode, GradientCode};
    use crate::decode::{decode_error, OptimalDecoder};

    fn random_a(k: usize, r: usize, s: usize, seed: u64) -> CscMatrix {
        let mut rng = Rng::new(seed);
        let g = BernoulliCode::new(k, k, s).assignment(&mut rng);
        g.select_columns(&rng.sample_indices(k, r))
    }

    #[test]
    fn curve_is_monotone_decreasing_with_spectral_nu() {
        let a = random_a(40, 30, 5, 1);
        let mut rng = Rng::new(2);
        let curve = algorithmic_error_curve(&a, StepSize::SpectralNormSq, 30, &mut rng);
        for w in curve.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "not monotone: {} -> {}", w[0], w[1]);
        }
        assert_eq!(curve[0], 40.0); // ||1_k||^2 = k
    }

    #[test]
    fn curve_converges_to_optimal_error() {
        let a = random_a(30, 25, 5, 3);
        let mut rng = Rng::new(4);
        let curve = algorithmic_error_curve(&a, StepSize::SpectralNormSq, 3000, &mut rng);
        let opt = OptimalDecoder::new().err(&a);
        let last = *curve.last().unwrap();
        assert!(
            (last - opt).abs() < 1e-4 * (1.0 + opt),
            "algorithmic {last} vs optimal {opt}"
        );
    }

    #[test]
    fn curve_upper_bounds_optimal_everywhere() {
        // Lemma 12: ||u_t||^2 >= err(A) for all t.
        let a = random_a(30, 20, 4, 5);
        let mut rng = Rng::new(6);
        let curve = algorithmic_error_curve(&a, StepSize::SpectralNormSq, 50, &mut rng);
        let opt = OptimalDecoder::new().err(&a);
        for (t, &e) in curve.iter().enumerate() {
            assert!(e >= opt - 1e-7, "t={t}: {e} < err(A)={opt}");
        }
    }

    #[test]
    fn weights_reproduce_iterate_error() {
        // decode_error(A, weights) must equal ||u_t||^2.
        let a = random_a(25, 20, 4, 7);
        let d = AlgorithmicDecoder::new(10, StepSize::SpectralNormSq);
        let w = d.weights(&a);
        let via_weights = decode_error(&a, &w);
        let via_iterate = d.error_at(&a);
        assert!((via_weights - via_iterate).abs() < 1e-8, "{via_weights} vs {via_iterate}");
    }

    #[test]
    fn zero_steps_is_identity() {
        let a = random_a(20, 10, 3, 8);
        let d = AlgorithmicDecoder::new(0, StepSize::SpectralNormSq);
        assert_eq!(d.error_at(&a), 20.0);
    }

    #[test]
    fn lemma17_stepsize_value() {
        let nu = StepSize::Lemma17 { k: 100, r: 80, s: 5 }
            .resolve(&CscMatrix::from_supports(1, vec![vec![0]]), &mut Rng::new(0));
        assert!((nu - 20.0).abs() < 1e-12);
    }
}
