//! Optimal decoding (paper Algorithm 2): x = argmin ||A x - 1_k||^2.
//!
//! err(A) (Definition 1) is the squared residual at the optimum. We
//! solve with LSQR on the sparse A (rank-deficiency safe: FRC submatrices
//! have duplicate columns); a dense normal-equation path exists for
//! cross-validation (`OptimalDecoder::dense_check`).

use super::Decoder;
use crate::linalg::{cholesky::solve_normal_equations, lsqr, CscMatrix, LsqrOptions};

#[derive(Clone, Debug)]
pub struct OptimalDecoder {
    pub opts: LsqrOptions,
}

impl Default for OptimalDecoder {
    fn default() -> Self {
        OptimalDecoder { opts: LsqrOptions::default() }
    }
}

impl OptimalDecoder {
    pub fn new() -> Self {
        Self::default()
    }

    /// err(A) = min_x ||A x - 1_k||^2.
    pub fn err(&self, a: &CscMatrix) -> f64 {
        if a.cols == 0 || a.nnz() == 0 {
            return a.rows as f64;
        }
        let b = vec![1.0; a.rows];
        let res = lsqr(a, &b, &self.opts);
        res.residual_norm * res.residual_norm
    }

    /// Dense cross-check via ridge-regularized normal equations. Only
    /// for small matrices (tests, exhaustive adversary).
    pub fn dense_check(&self, a: &CscMatrix) -> Option<f64> {
        let d = a.to_dense();
        let b = vec![1.0; a.rows];
        let x = solve_normal_equations(&d, &b, 1e-10)?;
        let ax = d.matvec(&x);
        Some(ax.iter().zip(&b).map(|(axi, bi)| (axi - bi).powi(2)).sum())
    }
}

impl Decoder for OptimalDecoder {
    fn weights(&self, a: &CscMatrix) -> Vec<f64> {
        if a.cols == 0 {
            return Vec::new();
        }
        let b = vec![1.0; a.rows];
        lsqr(a, &b, &self.opts).x
    }

    fn name(&self) -> &'static str {
        "optimal"
    }

    fn error(&self, a: &CscMatrix) -> f64 {
        self.err(a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::{BernoulliCode, FractionalRepetitionCode, GradientCode};
    use crate::decode::OneStepDecoder;
    use crate::util::Rng;

    #[test]
    fn identity_has_zero_error() {
        let a = CscMatrix::from_supports(4, (0..4).map(|i| vec![i]).collect());
        assert!(OptimalDecoder::new().err(&a) < 1e-18);
    }

    #[test]
    fn err_counts_uncovered_tasks_for_disjoint_supports() {
        // Two disjoint columns covering 3 of 5 tasks: err = 2.
        let a = CscMatrix::from_supports(5, vec![vec![0, 1], vec![2]]);
        let e = OptimalDecoder::new().err(&a);
        // Column [0,1] can only produce equal entries in rows 0,1: best is
        // x=1 exactly reproducing both. err = 5 - 3 = 2.
        assert!((e - 2.0).abs() < 1e-9, "{e}");
    }

    #[test]
    fn frc_error_is_multiple_of_s() {
        // Paper §3: err(A_frac) = αs where α = missing blocks.
        let code = FractionalRepetitionCode::new(20, 20, 5);
        let g = code.assignment(&mut Rng::new(1));
        // Keep workers only from blocks 0 and 2: blocks 1, 3 missing.
        let a = g.select_columns(&[0, 1, 10, 11]);
        let e = OptimalDecoder::new().err(&a);
        assert!((e - 10.0).abs() < 1e-8, "{e}");
    }

    #[test]
    fn optimal_never_exceeds_onestep() {
        let code = BernoulliCode::new(40, 40, 5);
        let mut rng = Rng::new(2);
        for trial in 0..10 {
            let g = code.assignment(&mut rng);
            let idx = rng.sample_indices(40, 30);
            let a = g.select_columns(&idx);
            let opt = OptimalDecoder::new().err(&a);
            let one = OneStepDecoder::canonical(40, 30, 5).err1(&a);
            assert!(
                opt <= one + 1e-8,
                "trial {trial}: optimal {opt} > one-step {one}"
            );
        }
    }

    #[test]
    fn lsqr_matches_dense_normal_equations() {
        let code = BernoulliCode::new(30, 30, 4);
        let mut rng = Rng::new(3);
        for _ in 0..10 {
            let g = code.assignment(&mut rng);
            let idx = rng.sample_indices(30, 20);
            let a = g.select_columns(&idx);
            let d = OptimalDecoder::new();
            let sparse = d.err(&a);
            let dense = d.dense_check(&a).unwrap();
            assert!((sparse - dense).abs() < 1e-5, "{sparse} vs {dense}");
        }
    }

    #[test]
    fn empty_a_gives_err_k() {
        let a = CscMatrix::from_supports(7, vec![]);
        assert_eq!(OptimalDecoder::new().err(&a), 7.0);
    }

    #[test]
    fn error_bounded_by_k() {
        let code = BernoulliCode::new(25, 25, 3);
        let mut rng = Rng::new(4);
        let g = code.assignment(&mut rng);
        let a = g.select_columns(&rng.sample_indices(25, 5));
        let e = OptimalDecoder::new().err(&a);
        assert!((0.0..=25.0 + 1e-9).contains(&e));
    }
}
