//! Decoding algorithms (paper Algorithms 1 & 2, Lemma 12) and the two
//! error functionals err(A) (Definition 1) and err_1(A) (Definition 2).
//!
//! A decoder produces a weight vector x over the r non-straggler
//! messages; the master's gradient estimate is then
//! ĝ = Σ_j x_j · msg_j, whose accuracy is governed by ||A x - 1_k||^2
//! (eq. 2.3: the recovery error is at most ||f||^2 · err).

pub mod algorithmic;
pub mod incremental;
pub mod onestep;
pub mod optimal;
pub mod panel;
pub mod workspace;

pub use algorithmic::{algorithmic_error_curve, AlgorithmicDecoder, StepSize};
pub use incremental::IncrementalDecoder;
pub use onestep::OneStepDecoder;
pub use panel::{PanelWorkspace, DEFAULT_PANEL_WIDTH};
pub use optimal::OptimalDecoder;
pub use workspace::{err1_from_supports, err1_streamed_counts, DecodeWorkspace};

use crate::linalg::{norm2_sq, CscMatrix};

/// A decoding method: weights over non-straggler messages.
pub trait Decoder {
    /// Weight vector x (length A.cols) approximating A x ≈ 1_k.
    fn weights(&self, a: &CscMatrix) -> Vec<f64>;
    fn name(&self) -> &'static str;

    /// The decoding error ||A x - 1_k||^2 achieved by this decoder on A.
    fn error(&self, a: &CscMatrix) -> f64 {
        let x = self.weights(a);
        decode_error(a, &x)
    }
}

///||A x - 1_k||^2 for a given weight vector.
pub fn decode_error(a: &CscMatrix, x: &[f64]) -> f64 {
    let ax = a.matvec(x);
    let diff: Vec<f64> = ax.iter().map(|v| v - 1.0).collect();
    norm2_sq(&diff)
}

/// The decoded approximation v = A x (the paper's "approximation to
/// 1_k"); applied to messages this is the master's gradient estimate.
pub fn decode_vector(a: &CscMatrix, x: &[f64]) -> Vec<f64> {
    a.matvec(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_error_of_exact_solution_is_zero() {
        // Identity: x = 1 reproduces 1_k.
        let a = CscMatrix::from_supports(3, vec![vec![0], vec![1], vec![2]]);
        assert_eq!(decode_error(&a, &[1.0, 1.0, 1.0]), 0.0);
    }

    #[test]
    fn decode_error_of_zero_weights_is_k() {
        let a = CscMatrix::from_supports(5, vec![vec![0, 1]]);
        assert_eq!(decode_error(&a, &[0.0]), 5.0);
    }
}
