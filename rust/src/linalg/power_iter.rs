//! Power iteration — spectral norm ||A||_2 and graph spectral gap.
//!
//! Two uses in the paper:
//!  * the algorithmic decoder's step size ν = ||A||_2^2 (Fig. 5 setting),
//!  * λ(G) = max{|λ2|, |λk|} for s-regular expander codes (Thm 3): for an
//!    s-regular graph the top eigenpair is (s, 1/sqrt(k)), so λ(G) is the
//!    spectral norm of the rank-1-deflated operator v -> Av - (s/k)(1^T v)1.

use super::sparse::CscMatrix;
use crate::util::Rng;

/// Estimate ||A||_2 via power iteration on A^T A. Deterministic given the
/// rng; relative accuracy ~1e-8 at the paper's problem sizes.
pub fn spectral_norm(a: &CscMatrix, rng: &mut Rng, max_iter: usize, tol: f64) -> f64 {
    let n = a.cols;
    if n == 0 || a.nnz() == 0 {
        return 0.0;
    }
    let mut v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let mut norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
    if norm == 0.0 {
        v[0] = 1.0;
        norm = 1.0;
    }
    for vi in v.iter_mut() {
        *vi /= norm;
    }
    let mut sigma_sq = 0.0;
    for _ in 0..max_iter {
        let av = a.matvec(&v);
        let atav = a.t_matvec(&av);
        let new_sigma_sq = atav.iter().map(|x| x * x).sum::<f64>().sqrt();
        if new_sigma_sq == 0.0 {
            return 0.0;
        }
        for (vi, wi) in v.iter_mut().zip(&atav) {
            *vi = wi / new_sigma_sq;
        }
        if (new_sigma_sq - sigma_sq).abs() <= tol * new_sigma_sq {
            sigma_sq = new_sigma_sq;
            break;
        }
        sigma_sq = new_sigma_sq;
    }
    sigma_sq.sqrt()
}

/// λ(G) = max{|λ2|, |λk|} for the adjacency matrix of an s-regular graph.
///
/// Power iteration on the deflated operator B = A - (s/k) J, whose
/// spectrum is {0} ∪ {λ2..λk}: its spectral norm is exactly λ(G).
pub fn regular_graph_lambda(adj: &CscMatrix, s: usize, rng: &mut Rng, max_iter: usize) -> f64 {
    assert_eq!(adj.rows, adj.cols, "adjacency must be square");
    let k = adj.rows;
    let shift = s as f64 / k as f64;
    let mut v: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
    // Remove the all-ones component up front.
    let mean = v.iter().sum::<f64>() / k as f64;
    for vi in v.iter_mut() {
        *vi -= mean;
    }
    let mut lambda = 0.0;
    for _ in 0..max_iter {
        let av = adj.matvec(&v);
        let ones_dot = v.iter().sum::<f64>();
        let mut w: Vec<f64> = av.iter().map(|&x| x - shift * ones_dot).collect();
        // Re-deflate to fight numerical drift back toward 1.
        let wm = w.iter().sum::<f64>() / k as f64;
        for wi in w.iter_mut() {
            *wi -= wm;
        }
        let norm = w.iter().map(|x| x * x).sum::<f64>().sqrt();
        if norm == 0.0 {
            return 0.0;
        }
        for wi in w.iter_mut() {
            *wi /= norm;
        }
        lambda = norm;
        v = w;
    }
    lambda
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spectral_norm_of_diagonal() {
        // diag(3, 1) -> ||A|| = 3
        let a = CscMatrix::from_columns(2, vec![vec![(0, 3.0)], vec![(1, 1.0)]]);
        let mut rng = Rng::new(1);
        let s = spectral_norm(&a, &mut rng, 200, 1e-12);
        assert!((s - 3.0).abs() < 1e-6, "{s}");
    }

    #[test]
    fn spectral_norm_of_ones_matrix() {
        // J (3x3): ||J|| = 3.
        let cols = (0..3).map(|_| (0..3).map(|i| (i, 1.0)).collect()).collect();
        let a = CscMatrix::from_columns(3, cols);
        let mut rng = Rng::new(2);
        let s = spectral_norm(&a, &mut rng, 200, 1e-12);
        assert!((s - 3.0).abs() < 1e-6, "{s}");
    }

    #[test]
    fn spectral_norm_zero_matrix() {
        let a = CscMatrix::from_supports(3, vec![vec![], vec![], vec![]]);
        let mut rng = Rng::new(3);
        assert_eq!(spectral_norm(&a, &mut rng, 50, 1e-10), 0.0);
    }

    #[test]
    fn lambda_of_complete_graph() {
        // K_4 is 3-regular with eigenvalues {3, -1, -1, -1}: λ(G) = 1.
        let k = 4;
        let cols: Vec<Vec<usize>> =
            (0..k).map(|j| (0..k).filter(|&i| i != j).collect()).collect();
        let adj = CscMatrix::from_supports(k, cols);
        let mut rng = Rng::new(4);
        let l = regular_graph_lambda(&adj, 3, &mut rng, 300);
        assert!((l - 1.0).abs() < 1e-6, "{l}");
    }

    #[test]
    fn lambda_of_cycle() {
        // C_6 is 2-regular; λ(G) = max |2 cos(2πj/6)| over j=1..5 = 2cos(π/3)*... = 2*cos(60°)=1? Actually eigenvalues 2cos(2πj/6): {2, 1, -1, -2, -1, 1} -> λ = 2 (the -2 from bipartiteness).
        let k = 6;
        let cols: Vec<Vec<usize>> =
            (0..k).map(|j| vec![(j + 1) % k, (j + k - 1) % k]).collect();
        let adj = CscMatrix::from_supports(k, cols);
        let mut rng = Rng::new(5);
        let l = regular_graph_lambda(&adj, 2, &mut rng, 500);
        assert!((l - 2.0).abs() < 1e-4, "{l}");
    }
}
