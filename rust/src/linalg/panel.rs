//! Multi-RHS **panel** kernels: W concurrent decode trials against one
//! shared G, one pass over G's nonzeros serving all W lanes.
//!
//! Every Monte-Carlo point used to solve its trials one at a time, so
//! each kernel invocation streamed G's index/value arrays from memory
//! for a single trial — the classic bandwidth-bound shape. The panel
//! kernels here batch W trials ("lanes") into one call: the coverage
//! pass reads each CSR row once and feeds W coverage accumulators, and
//! the panel LSQR runs W solves in iteration lockstep over the same G,
//! so G's columns stay cache-resident across lanes.
//!
//! # Bit-parity contract
//!
//! Per-lane results are **bit-identical to the scalar path at any W**
//! (pinned by `tests/decode_parity.rs`). Two mechanisms make that hold:
//!
//! * **Selected-submatrix kernels.** `select_columns_into` copies G's
//!   column slices verbatim, so a matvec on A = G[:, sel] is *the same
//!   arithmetic* as walking G's columns in `sel` order.
//!   [`matvec_selected_into`] / [`t_matvec_selected_into`] do exactly
//!   that — A is never materialized, and every addition happens in the
//!   order the materialized kernels would use.
//! * **Integer-exact coverage.** On boolean G (every code the paper
//!   constructs) the per-row coverage counts are integers below 2⁵³,
//!   and integer-valued f64 sums are exact under *any* accumulation
//!   order (the [`blocked`] convention note). The panel coverage kernel
//!   may therefore interleave lanes freely; the per-lane err₁ reduction
//!   then sweeps rows 0..k sequentially — the same final reduction
//!   order as `err1_from_supports` / `err1_streamed_counts`.
//!
//! The panel LSQR needs no such argument: each lane executes the
//! `lsqr_with` sequence operation for operation (same blocked kernels,
//! same Givens updates, same stopping rules), lanes merely take their
//! iterations in lockstep so G is reused across lanes per iteration.
//!
//! # SIMD lane tiers (`--features simd`)
//!
//! The lane-inner loops (coverage [`axpy_lanes`] and the per-row err₁
//! update) are the one place true SIMD applies cleanly: lanes are
//! independent accumulators, so packing 2 (SSE2 `__m128d`), 4 (AVX2
//! `__m256d`), or 8 (AVX-512 `__m512d`) of them into one register
//! performs the *same* IEEE mul/add per element as the scalar loop —
//! bit-identical by construction at every tier. No FMA is ever used
//! (contraction would change rounding), and `(x).powi(2)` is a single
//! self-multiply, so the vector `mul(t, t)` matches it exactly.
//!
//! The portable loop is the default. Under the `simd` cargo feature on
//! x86_64, [`super::tier::simd_tier`] picks the widest tier the CPU
//! supports at runtime (`is_x86_feature_detected!`): SSE2 is baseline,
//! AVX2 is detected, and the AVX-512F tier additionally needs the
//! `avx512` cargo feature (toolchain gate — see `linalg::tier`).
//! Non-x86 targets fall back to the portable loop regardless of
//! features.

use super::blocked;
use super::csr::CsrMatrix;
use super::lsqr::{LsqrOptions, LsqrSummary};
use super::sparse::CscMatrix;
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
use super::tier::{simd_tier, SimdTier};

/// nnz of the implicit selection A = G[:, sel] (multiplicity counts).
pub fn nnz_selected(g: &CscMatrix, sel: &[usize]) -> usize {
    sel.iter().map(|&j| g.col_nnz(j)).sum()
}

/// y = A x for the implicit selection A = G[:, sel], without
/// materializing A. Bit-identical to `g.select_columns(sel)` followed
/// by `matvec_into`: A's column jj is G's column sel\[jj\] verbatim, so
/// the scatter sequence is the same addition for addition.
pub fn matvec_selected_into(g: &CscMatrix, sel: &[usize], x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), sel.len());
    assert_eq!(y.len(), g.rows);
    y.fill(0.0);
    for (jj, &j) in sel.iter().enumerate() {
        assert!(j < g.cols, "column {j} out of bounds ({})", g.cols);
        let xj = x[jj];
        if xj == 0.0 {
            continue;
        }
        for p in g.col_ptr[j]..g.col_ptr[j + 1] {
            y[g.row_idx[p]] += g.vals[p] * xj;
        }
    }
}

/// y = Aᵀ x for the implicit selection A = G[:, sel]. Bit-identical to
/// the materialized `t_matvec_into` (per-column sequential accumulator,
/// same visit order).
pub fn t_matvec_selected_into(g: &CscMatrix, sel: &[usize], x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), g.rows);
    assert_eq!(y.len(), sel.len());
    for (jj, &j) in sel.iter().enumerate() {
        assert!(j < g.cols, "column {j} out of bounds ({})", g.cols);
        let mut acc = 0.0;
        for p in g.col_ptr[j]..g.col_ptr[j + 1] {
            acc += g.vals[p] * x[g.row_idx[p]];
        }
        y[jj] = acc;
    }
}

/// SSE2 tier of [`axpy_lanes`]: lane pairs in `__m128d`.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
fn axpy_lanes_sse2(cov: &mut [f64], v: f64, counts: &[f64]) {
    use std::arch::x86_64::{_mm_add_pd, _mm_loadu_pd, _mm_mul_pd, _mm_set1_pd, _mm_storeu_pd};
    let pairs = cov.len() / 2;
    // SAFETY: SSE2 is baseline on x86_64; all loads/stores stay in
    // bounds (2*q + 1 < cov.len() and counts.len() >= cov.len()).
    unsafe {
        let vv = _mm_set1_pd(v);
        for q in 0..pairs {
            let c = _mm_loadu_pd(counts.as_ptr().add(2 * q));
            let acc = _mm_loadu_pd(cov.as_ptr().add(2 * q));
            _mm_storeu_pd(cov.as_mut_ptr().add(2 * q), _mm_add_pd(acc, _mm_mul_pd(vv, c)));
        }
    }
    for l in 2 * pairs..cov.len() {
        cov[l] += v * counts[l];
    }
}

/// AVX2 tier of [`axpy_lanes`]: lane quads in `__m256d`. Same IEEE
/// mul/add per lane as the scalar loop; no FMA.
///
/// # Safety
/// The CPU must support AVX2 (callers dispatch on [`simd_tier`]).
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[target_feature(enable = "avx2")]
unsafe fn axpy_lanes_avx2(cov: &mut [f64], v: f64, counts: &[f64]) {
    use std::arch::x86_64::{
        _mm256_add_pd, _mm256_loadu_pd, _mm256_mul_pd, _mm256_set1_pd, _mm256_storeu_pd,
    };
    let quads = cov.len() / 4;
    let vv = _mm256_set1_pd(v);
    for q in 0..quads {
        let c = _mm256_loadu_pd(counts.as_ptr().add(4 * q));
        let acc = _mm256_loadu_pd(cov.as_ptr().add(4 * q));
        _mm256_storeu_pd(cov.as_mut_ptr().add(4 * q), _mm256_add_pd(acc, _mm256_mul_pd(vv, c)));
    }
    for l in 4 * quads..cov.len() {
        cov[l] += v * counts[l];
    }
}

/// AVX-512F tier of [`axpy_lanes`]: lane octets in `__m512d`.
///
/// # Safety
/// The CPU must support AVX-512F (callers dispatch on [`simd_tier`]).
#[cfg(all(feature = "simd", feature = "avx512", target_arch = "x86_64"))]
#[target_feature(enable = "avx512f")]
unsafe fn axpy_lanes_avx512(cov: &mut [f64], v: f64, counts: &[f64]) {
    use std::arch::x86_64::{
        _mm512_add_pd, _mm512_loadu_pd, _mm512_mul_pd, _mm512_set1_pd, _mm512_storeu_pd,
    };
    let octets = cov.len() / 8;
    let vv = _mm512_set1_pd(v);
    for q in 0..octets {
        let c = _mm512_loadu_pd(counts.as_ptr().add(8 * q));
        let acc = _mm512_loadu_pd(cov.as_ptr().add(8 * q));
        _mm512_storeu_pd(cov.as_mut_ptr().add(8 * q), _mm512_add_pd(acc, _mm512_mul_pd(vv, c)));
    }
    for l in 8 * octets..cov.len() {
        cov[l] += v * counts[l];
    }
}

/// `cov[l] += v * counts[l]` for every lane — the panel coverage
/// kernel's inner loop. With `--features simd` on x86_64 this dispatches
/// on the runtime [`simd_tier`] (SSE2 pairs / AVX2 quads / AVX-512
/// octets); per-element IEEE mul/add on independent lanes is
/// bit-identical to the scalar loop at every tier, so all paths are
/// interchangeable.
#[inline]
fn axpy_lanes(cov: &mut [f64], v: f64, counts: &[f64]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        let tier = simd_tier();
        #[cfg(feature = "avx512")]
        if tier == SimdTier::Avx512 {
            // SAFETY: dispatch is guarded by runtime avx512f detection.
            unsafe { axpy_lanes_avx512(cov, v, counts) };
            return;
        }
        if tier >= SimdTier::Avx2 {
            // SAFETY: dispatch is guarded by runtime avx2 detection.
            unsafe { axpy_lanes_avx2(cov, v, counts) };
            return;
        }
        if tier == SimdTier::Sse2 {
            axpy_lanes_sse2(cov, v, counts);
            return;
        }
        // SimdTier::Portable (bench tier cap): fall through.
    }
    for l in 0..cov.len() {
        cov[l] += v * counts[l];
    }
}

/// SSE2 tier of [`err_update_lanes`].
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
fn err_update_lanes_sse2(errs: &mut [f64], rho: f64, cov: &[f64]) {
    use std::arch::x86_64::{
        _mm_add_pd, _mm_loadu_pd, _mm_mul_pd, _mm_set1_pd, _mm_storeu_pd, _mm_sub_pd,
    };
    let pairs = errs.len() / 2;
    // SAFETY: SSE2 is baseline on x86_64; loads/stores stay in bounds.
    unsafe {
        let rv = _mm_set1_pd(rho);
        let one = _mm_set1_pd(1.0);
        for q in 0..pairs {
            let c = _mm_loadu_pd(cov.as_ptr().add(2 * q));
            let t = _mm_sub_pd(_mm_mul_pd(rv, c), one);
            let e = _mm_loadu_pd(errs.as_ptr().add(2 * q));
            _mm_storeu_pd(errs.as_mut_ptr().add(2 * q), _mm_add_pd(e, _mm_mul_pd(t, t)));
        }
    }
    for l in 2 * pairs..errs.len() {
        errs[l] += (rho * cov[l] - 1.0).powi(2);
    }
}

/// AVX2 tier of [`err_update_lanes`].
///
/// # Safety
/// The CPU must support AVX2 (callers dispatch on [`simd_tier`]).
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[target_feature(enable = "avx2")]
unsafe fn err_update_lanes_avx2(errs: &mut [f64], rho: f64, cov: &[f64]) {
    use std::arch::x86_64::{
        _mm256_add_pd, _mm256_loadu_pd, _mm256_mul_pd, _mm256_set1_pd, _mm256_storeu_pd,
        _mm256_sub_pd,
    };
    let quads = errs.len() / 4;
    let rv = _mm256_set1_pd(rho);
    let one = _mm256_set1_pd(1.0);
    for q in 0..quads {
        let c = _mm256_loadu_pd(cov.as_ptr().add(4 * q));
        let t = _mm256_sub_pd(_mm256_mul_pd(rv, c), one);
        let e = _mm256_loadu_pd(errs.as_ptr().add(4 * q));
        _mm256_storeu_pd(errs.as_mut_ptr().add(4 * q), _mm256_add_pd(e, _mm256_mul_pd(t, t)));
    }
    for l in 4 * quads..errs.len() {
        errs[l] += (rho * cov[l] - 1.0).powi(2);
    }
}

/// AVX-512F tier of [`err_update_lanes`].
///
/// # Safety
/// The CPU must support AVX-512F (callers dispatch on [`simd_tier`]).
#[cfg(all(feature = "simd", feature = "avx512", target_arch = "x86_64"))]
#[target_feature(enable = "avx512f")]
unsafe fn err_update_lanes_avx512(errs: &mut [f64], rho: f64, cov: &[f64]) {
    use std::arch::x86_64::{
        _mm512_add_pd, _mm512_loadu_pd, _mm512_mul_pd, _mm512_set1_pd, _mm512_storeu_pd,
        _mm512_sub_pd,
    };
    let octets = errs.len() / 8;
    let rv = _mm512_set1_pd(rho);
    let one = _mm512_set1_pd(1.0);
    for q in 0..octets {
        let c = _mm512_loadu_pd(cov.as_ptr().add(8 * q));
        let t = _mm512_sub_pd(_mm512_mul_pd(rv, c), one);
        let e = _mm512_loadu_pd(errs.as_ptr().add(8 * q));
        _mm512_storeu_pd(errs.as_mut_ptr().add(8 * q), _mm512_add_pd(e, _mm512_mul_pd(t, t)));
    }
    for l in 8 * octets..errs.len() {
        errs[l] += (rho * cov[l] - 1.0).powi(2);
    }
}

/// `errs[l] += (ρ·cov[l] − 1)²` for every lane — the per-row err₁
/// update shared by [`err1_panel_counts`] and [`err1_panel_cov`].
/// `.powi(2)` is a single self-multiply, so the vector `mul(t, t)` is
/// the same IEEE operation; no FMA at any tier, hence bit-identical to
/// the scalar loop.
#[inline]
fn err_update_lanes(errs: &mut [f64], rho: f64, cov: &[f64]) {
    debug_assert_eq!(errs.len(), cov.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        let tier = simd_tier();
        #[cfg(feature = "avx512")]
        if tier == SimdTier::Avx512 {
            // SAFETY: dispatch is guarded by runtime avx512f detection.
            unsafe { err_update_lanes_avx512(errs, rho, cov) };
            return;
        }
        if tier >= SimdTier::Avx2 {
            // SAFETY: dispatch is guarded by runtime avx2 detection.
            unsafe { err_update_lanes_avx2(errs, rho, cov) };
            return;
        }
        if tier == SimdTier::Sse2 {
            err_update_lanes_sse2(errs, rho, cov);
            return;
        }
        // SimdTier::Portable (bench tier cap): fall through.
    }
    for l in 0..errs.len() {
        errs[l] += (rho * cov[l] - 1.0).powi(2);
    }
}

/// Panel one-step error: W trials' err₁ values in one pass over G.
///
/// `counts` is the k-trial coverage-count panel, lane-contiguous per
/// column: `counts[j * width + l]` is column j's selection multiplicity
/// in lane l (0 for that lane's stragglers). Each CSR row of G is read
/// **once** and accumulates into all W lane coverages; `errs[l]`
/// receives `Σ_i (ρ·cov_{i,l} − 1)²` with the row sweep in ascending
/// order — the same final reduction as the scalar paths.
///
/// Exactness requires integer-valued data (boolean G × integer counts);
/// callers with weighted G should use the per-lane scalar path instead.
pub fn err1_panel_counts(
    g: &CsrMatrix,
    counts: &[f64],
    width: usize,
    rho: f64,
    cov: &mut [f64],
    errs: &mut [f64],
) {
    assert!(width > 0, "panel width must be >= 1");
    assert_eq!(counts.len(), g.cols * width, "counts panel shape mismatch");
    assert_eq!(cov.len(), width);
    assert_eq!(errs.len(), width);
    errs.fill(0.0);
    for i in 0..g.rows {
        cov.fill(0.0);
        for p in g.row_ptr[i]..g.row_ptr[i + 1] {
            let base = g.col_idx[p] * width;
            axpy_lanes(cov, g.vals[p], &counts[base..base + width]);
        }
        err_update_lanes(errs, rho, cov);
    }
}

/// Per-lane err₁ from a lane-strided coverage panel: `errs[l] =
/// Σ_i (ρ·cov_panel[i·width + l] − 1)²`, rows swept in ascending order —
/// the same final reduction as `err1_from_supports`.
///
/// Backs the fused redraw panel
/// (`decode::PanelWorkspace::onestep_redraw_panel_with`), where each
/// lane's coverage row was scatter-accumulated from that lane's own G
/// in scalar selection order. No integer-exactness argument is needed
/// here (unlike [`err1_panel_counts`]): lane l's additions *are* the
/// scalar trial's additions, operation for operation, so the panel is
/// bit-identical to the scalar path even on weighted G.
pub fn err1_panel_cov(cov_panel: &[f64], width: usize, rho: f64, errs: &mut [f64]) {
    assert!(width > 0, "panel width must be >= 1");
    assert_eq!(errs.len(), width);
    assert_eq!(cov_panel.len() % width, 0, "coverage panel shape mismatch");
    errs.fill(0.0);
    for row in cov_panel.chunks_exact(width) {
        err_update_lanes(errs, rho, row);
    }
}

/// One lane's LSQR state — the per-solve vectors and scalars of
/// `lsqr_with`, owned per lane so lanes can advance in lockstep.
#[derive(Clone, Debug, Default)]
struct LsqrLane {
    u: Vec<f64>,
    v: Vec<f64>,
    w: Vec<f64>,
    x: Vec<f64>,
    av: Vec<f64>,
    atu: Vec<f64>,
    alpha: f64,
    beta: f64,
    phi_bar: f64,
    rho_bar: f64,
    b_norm: f64,
    a_norm_sq: f64,
    max_iter: usize,
    iterations: usize,
    done: bool,
    converged: bool,
    residual_norm: f64,
}

/// Reusable scratch for [`lsqr_selected_panel`]: one [`LsqrLane`] per
/// panel lane plus the shared warm-start buffer. Buffers grow to the
/// largest instance seen and are reused, so a steady-state panel loop
/// performs no heap allocation.
#[derive(Clone, Debug, Default)]
pub struct PanelLsqr {
    lanes: Vec<LsqrLane>,
    x0: Vec<f64>,
}

impl PanelLsqr {
    pub fn new() -> Self {
        Self::default()
    }

    /// The solution vector lane `l` converged to in the most recent
    /// [`lsqr_selected_panel`] call (exposed for parity tests).
    pub fn lane_x(&self, l: usize) -> &[f64] {
        &self.lanes[l].x
    }
}

/// Multi-RHS LSQR over implicit selections of one shared G: for every
/// lane `l` in `active`, solve `min_x ||G[:, sel_l] x − b||` where
/// `sel_l = sel_flat[sel_ptr[l]..sel_ptr[l+1]]`, writing the per-lane
/// [`LsqrSummary`] into `out[l]`.
///
/// Lanes advance in **iteration lockstep** — every live lane takes
/// iteration t before any lane takes t+1 — so each LSQR iteration's two
/// passes over G serve all W lanes while G's arrays are hot. Converged
/// lanes freeze. Per lane, the arithmetic is the `lsqr_with` sequence
/// operation for operation (same blocked kernels, same Givens rotation,
/// same Paige-Saunders stopping rules, same true-residual recompute),
/// with the selected-submatrix kernels standing in for the materialized
/// matvecs — so each lane's summary and solution are bit-identical to a
/// scalar solve on the materialized A.
///
/// `warm = Some(rho)` warm-starts every lane at ρ·1 (the one-step
/// weights), matching the scalar `optimal_err(.., Some(rho))` path.
/// Degenerate lanes (empty selection / zero nnz) must be filtered out
/// of `active` by the caller, which owns the `err = k` convention.
#[allow(clippy::too_many_arguments)] // mirrors the scalar lsqr_with surface
pub fn lsqr_selected_panel(
    g: &CscMatrix,
    sel_flat: &[usize],
    sel_ptr: &[usize],
    active: &[usize],
    b: &[f64],
    opts: &LsqrOptions,
    warm: Option<f64>,
    ws: &mut PanelLsqr,
    out: &mut [LsqrSummary],
) {
    let m = g.rows;
    assert_eq!(b.len(), m);
    assert!(sel_ptr.len() >= 2 || active.is_empty(), "sel_ptr must cover every lane");
    let num_lanes = sel_ptr.len().saturating_sub(1);
    if ws.lanes.len() < num_lanes {
        ws.lanes.resize_with(num_lanes, LsqrLane::default);
    }
    let PanelLsqr { lanes, x0 } = ws;

    // ---- per-lane initialization (the lsqr_with prologue, verbatim)
    for &l in active {
        let sel = &sel_flat[sel_ptr[l]..sel_ptr[l + 1]];
        let n = sel.len();
        let lane = &mut lanes[l];
        lane.max_iter = if opts.max_iter == 0 { 4 * m.max(n) } else { opts.max_iter };
        lane.iterations = 0;
        lane.done = false;
        lane.converged = false;

        lane.x.clear();
        lane.x.resize(n, 0.0);
        lane.v.clear();
        lane.v.resize(n, 0.0);
        lane.w.clear();
        lane.w.resize(n, 0.0);
        lane.av.clear();
        lane.av.resize(m, 0.0);
        lane.atu.clear();
        lane.atu.resize(n, 0.0);

        lane.u.clear();
        lane.u.extend_from_slice(b);
        if let Some(rho) = warm {
            x0.clear();
            x0.resize(n, rho);
            matvec_selected_into(g, sel, x0, &mut lane.av);
            for i in 0..m {
                lane.u[i] -= lane.av[i];
            }
        }

        lane.beta = blocked::norm2(&lane.u);
        if lane.beta == 0.0 {
            // rhs already reproduced exactly: x = x0.
            if let Some(rho) = warm {
                for xi in lane.x.iter_mut() {
                    *xi = rho;
                }
            }
            lane.residual_norm = 0.0;
            lane.converged = true;
            lane.done = true;
            continue;
        }
        for ui in lane.u.iter_mut() {
            *ui /= lane.beta;
        }
        t_matvec_selected_into(g, sel, &lane.u, &mut lane.v);
        lane.alpha = blocked::norm2(&lane.v);
        if lane.alpha == 0.0 {
            // rhs orthogonal to range(A): dx = 0 is optimal.
            if let Some(rho) = warm {
                for xi in lane.x.iter_mut() {
                    *xi = rho;
                }
            }
            lane.residual_norm = lane.beta;
            lane.converged = true;
            lane.done = true;
            continue;
        }
        for vi in lane.v.iter_mut() {
            *vi /= lane.alpha;
        }
        lane.w.copy_from_slice(&lane.v);
        lane.phi_bar = lane.beta;
        lane.rho_bar = lane.alpha;
        lane.b_norm = lane.beta;
        lane.a_norm_sq = 0.0;
    }

    // ---- lockstep iterations: every live lane takes step t together.
    loop {
        let mut any_live = false;
        for &l in active {
            let sel = &sel_flat[sel_ptr[l]..sel_ptr[l + 1]];
            let lane = &mut lanes[l];
            if lane.done {
                continue;
            }
            any_live = true;
            lane.iterations += 1;

            // u = A v - alpha u; beta = ||u||
            matvec_selected_into(g, sel, &lane.v, &mut lane.av);
            blocked::scaled_sub(&lane.av, lane.alpha, &mut lane.u);
            lane.beta = blocked::norm2(&lane.u);
            if lane.beta > 0.0 {
                for ui in lane.u.iter_mut() {
                    *ui /= lane.beta;
                }
            }

            // v = A^T u - beta v; alpha = ||v||
            t_matvec_selected_into(g, sel, &lane.u, &mut lane.atu);
            blocked::scaled_sub(&lane.atu, lane.beta, &mut lane.v);
            lane.alpha = blocked::norm2(&lane.v);
            if lane.alpha > 0.0 {
                for vi in lane.v.iter_mut() {
                    *vi /= lane.alpha;
                }
            }

            lane.a_norm_sq += lane.alpha * lane.alpha + lane.beta * lane.beta;

            // Givens rotation to eliminate beta from the bidiagonal system.
            let rho_g = (lane.rho_bar * lane.rho_bar + lane.beta * lane.beta).sqrt();
            let c = lane.rho_bar / rho_g;
            let s = lane.beta / rho_g;
            let theta = s * lane.alpha;
            lane.rho_bar = -c * lane.alpha;
            let phi = c * lane.phi_bar;
            lane.phi_bar *= s;

            // Update x and the search direction w.
            let t1 = phi / rho_g;
            let t2 = -theta / rho_g;
            blocked::update_x_w(&mut lane.x, &mut lane.w, &lane.v, t1, t2);

            // Stopping rules (Paige-Saunders criteria 1 & 2).
            let res = lane.phi_bar;
            let a_norm = lane.a_norm_sq.sqrt();
            let atr = lane.phi_bar * lane.alpha * c.abs();
            if res <= opts.btol * lane.b_norm + opts.atol * a_norm * blocked::norm2(&lane.x) {
                lane.converged = true;
            } else if a_norm > 0.0 && res > 0.0 && atr / (a_norm * res) <= opts.atol {
                lane.converged = true;
            } else if lane.alpha == 0.0 {
                lane.converged = true;
            }
            if lane.converged || lane.iterations == lane.max_iter {
                // Fold the warm start back in, then recompute the true
                // residual (phi_bar is an estimate) without allocating.
                if let Some(rho) = warm {
                    for xi in lane.x.iter_mut() {
                        *xi += rho;
                    }
                }
                matvec_selected_into(g, sel, &lane.x, &mut lane.av);
                lane.residual_norm = blocked::diff_norm2_sq(b, &lane.av).sqrt();
                lane.done = true;
            }
        }
        if !any_live {
            break;
        }
    }

    for &l in active {
        let lane = &lanes[l];
        out[l] = LsqrSummary {
            residual_norm: lane.residual_norm,
            iterations: lane.iterations,
            converged: lane.converged,
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{lsqr_with, LsqrWorkspace};
    use crate::util::Rng;

    fn random_boolean_g(k: usize, n: usize, p: f64, seed: u64) -> CscMatrix {
        let mut rng = Rng::new(seed);
        let cols: Vec<Vec<usize>> = (0..n)
            .map(|_| (0..k).filter(|_| rng.f64() < p).collect())
            .collect();
        CscMatrix::from_supports(k, cols)
    }

    #[test]
    fn selected_matvecs_bit_identical_to_materialized() {
        let g = random_boolean_g(25, 30, 0.2, 1);
        let mut rng = Rng::new(2);
        for trial in 0..15 {
            let r = 1 + rng.usize(30);
            let sel = rng.sample_indices(30, r);
            let a = g.select_columns(&sel);
            let x: Vec<f64> = (0..r).map(|_| rng.normal()).collect();
            let mut y_sel = vec![0.0; 25];
            matvec_selected_into(&g, &sel, &x, &mut y_sel);
            let y_mat = a.matvec(&x);
            for (s, m) in y_sel.iter().zip(&y_mat) {
                assert_eq!(s.to_bits(), m.to_bits(), "matvec trial {trial}");
            }
            let xr: Vec<f64> = (0..25).map(|_| rng.normal()).collect();
            let mut yt_sel = vec![0.0; r];
            t_matvec_selected_into(&g, &sel, &xr, &mut yt_sel);
            let yt_mat = a.t_matvec(&xr);
            for (s, m) in yt_sel.iter().zip(&yt_mat) {
                assert_eq!(s.to_bits(), m.to_bits(), "t_matvec trial {trial}");
            }
            assert_eq!(nnz_selected(&g, &sel), a.nnz());
        }
    }

    #[test]
    fn panel_err1_matches_scalar_per_lane_all_widths() {
        use crate::decode::err1_from_supports;
        let g = random_boolean_g(30, 40, 0.15, 3);
        let csr = g.to_csr();
        let rho = 0.37;
        let mut row_acc = Vec::new();
        let mut rng = Rng::new(4);
        for width in [1usize, 2, 3, 4, 8] {
            let sels: Vec<Vec<usize>> =
                (0..width).map(|_| rng.sample_indices(40, 25)).collect();
            let mut counts = vec![0.0; 40 * width];
            for (l, sel) in sels.iter().enumerate() {
                for &j in sel {
                    counts[j * width + l] += 1.0;
                }
            }
            let mut cov = vec![0.0; width];
            let mut errs = vec![0.0; width];
            err1_panel_counts(&csr, &counts, width, rho, &mut cov, &mut errs);
            for (l, sel) in sels.iter().enumerate() {
                let scalar = err1_from_supports(&g, sel, rho, &mut row_acc);
                assert_eq!(errs[l].to_bits(), scalar.to_bits(), "width {width} lane {l}");
            }
        }
    }

    #[test]
    fn panel_cov_err1_matches_scalar_reduction_all_widths() {
        let k = 23usize;
        let rho = 0.41;
        let mut rng = Rng::new(7);
        for width in [1usize, 2, 3, 5, 8, 16] {
            // Non-integer coverages on purpose: err1_panel_cov carries no
            // integer-exactness requirement (weighted-G redraw panels).
            let cov_panel: Vec<f64> = (0..k * width).map(|_| rng.f64() * 3.0).collect();
            let mut errs = vec![0.0; width];
            err1_panel_cov(&cov_panel, width, rho, &mut errs);
            for l in 0..width {
                let scalar: f64 = (0..k)
                    .map(|i| (rho * cov_panel[i * width + l] - 1.0).powi(2))
                    .sum();
                assert_eq!(errs[l].to_bits(), scalar.to_bits(), "width {width} lane {l}");
            }
        }
    }

    #[test]
    fn panel_lsqr_bit_identical_to_scalar_on_materialized_a() {
        let g = random_boolean_g(24, 30, 0.2, 5);
        let b = vec![1.0; 24];
        let opts = LsqrOptions::default();
        let mut rng = Rng::new(6);
        for warm in [None, Some(0.3)] {
            let width = 4usize;
            let sels: Vec<Vec<usize>> =
                (0..width).map(|_| rng.sample_indices(30, 18)).collect();
            let mut sel_flat = Vec::new();
            let mut sel_ptr = vec![0usize];
            for sel in &sels {
                sel_flat.extend_from_slice(sel);
                sel_ptr.push(sel_flat.len());
            }
            let active: Vec<usize> = (0..width).collect();
            let mut pls = PanelLsqr::new();
            let mut out =
                vec![LsqrSummary { residual_norm: 0.0, iterations: 0, converged: false }; width];
            lsqr_selected_panel(&g, &sel_flat, &sel_ptr, &active, &b, &opts, warm, &mut pls, &mut out);

            let mut ws = LsqrWorkspace::new();
            for (l, sel) in sels.iter().enumerate() {
                let a = g.select_columns(sel);
                let x0_buf: Vec<f64>;
                let x0: Option<&[f64]> = match warm {
                    Some(rho) => {
                        x0_buf = vec![rho; a.cols];
                        Some(&x0_buf)
                    }
                    None => None,
                };
                let reference = lsqr_with(&a, &b, &opts, x0, &mut ws);
                assert_eq!(
                    out[l].residual_norm.to_bits(),
                    reference.residual_norm.to_bits(),
                    "warm {warm:?} lane {l}"
                );
                assert_eq!(out[l].iterations, reference.iterations, "lane {l}");
                assert_eq!(out[l].converged, reference.converged, "lane {l}");
                assert_eq!(pls.lane_x(l), ws.x(), "warm {warm:?} lane {l}");
            }
        }
    }
}
