//! Compressed-sparse-row mirror of [`CscMatrix`] — the row-major fast
//! path for the decoders' repeated row passes (row coverage, row sums,
//! the streamed one-step error).
//!
//! CSC stays the *native* representation (the paper's objects are
//! column-wise: columns are workers, straggler removal is a column
//! selection). But the decode inner loops are row reductions, which in
//! CSC scatter through memory; the CSR twin streams them contiguously.
//! A mirror is built once per G with [`CscMatrix::to_csr`] /
//! [`CscMatrix::to_csr_into`] and cached in `decode::DecodeWorkspace`.
//!
//! **Order guarantee**: the conversion is a stable counting-sort
//! transpose, so within each CSR row the entries appear in ascending
//! column order — exactly the order in which the CSC kernels visit
//! them. Every `CsrMatrix` kernel below therefore accumulates in the
//! *same sequence* as its `CscMatrix` counterpart and is bit-identical
//! to it (pinned by `tests/linalg_parity.rs`), not merely close.

use super::dense::DenseMatrix;
use super::sparse::CscMatrix;

/// Sparse matrix in CSR layout with explicit f64 values.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    pub rows: usize,
    pub cols: usize,
    /// `row_ptr[i]..row_ptr[i+1]` indexes `col_idx`/`vals` for row i.
    pub row_ptr: Vec<usize>,
    pub col_idx: Vec<usize>,
    pub vals: Vec<f64>,
}

impl CsrMatrix {
    /// An empty 0×0 matrix — the starting state for workspace-cached
    /// mirrors filled via [`CscMatrix::to_csr_into`].
    pub fn empty() -> CsrMatrix {
        CsrMatrix { rows: 0, cols: 0, row_ptr: vec![0], col_idx: Vec::new(), vals: Vec::new() }
    }

    /// Allocating conversion (see [`CscMatrix::to_csr_into`] for the
    /// buffer-reusing hot-path variant).
    pub fn from_csc(csc: &CscMatrix) -> CsrMatrix {
        let mut out = CsrMatrix::empty();
        csc.to_csr_into(&mut out);
        out
    }

    pub fn nnz(&self) -> usize {
        self.col_idx.len()
    }

    /// Entries of row i as (col, value) pairs, in ascending column order.
    pub fn row(&self, i: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let range = self.row_ptr[i]..self.row_ptr[i + 1];
        self.col_idx[range.clone()].iter().copied().zip(self.vals[range].iter().copied())
    }

    pub fn row_nnz(&self, i: usize) -> usize {
        self.row_ptr[i + 1] - self.row_ptr[i]
    }

    /// y = A x (x over columns). Bit-identical to [`CscMatrix::matvec`]:
    /// both add the (nonzero-x) terms of each row in ascending column
    /// order — CSR just does it in one contiguous sweep per row instead
    /// of scattering across the column walk.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// y = A x into a caller-provided buffer.
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for i in 0..self.rows {
            let mut acc = 0.0;
            for p in self.row_ptr[i]..self.row_ptr[i + 1] {
                let xj = x[self.col_idx[p]];
                // The CSC path skips zero x entries at the column level;
                // skipping here keeps the exact same addition sequence.
                if xj == 0.0 {
                    continue;
                }
                acc += self.vals[p] * xj;
            }
            y[i] = acc;
        }
    }

    /// y = A^T x (x over rows). Bit-identical to
    /// [`CscMatrix::t_matvec`]: each output column accumulates its
    /// terms in ascending row order in both layouts.
    pub fn t_matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.cols];
        self.t_matvec_into(x, &mut y);
        y
    }

    /// y = A^T x into a caller-provided buffer.
    pub fn t_matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        y.fill(0.0);
        for i in 0..self.rows {
            let xi = x[i];
            for p in self.row_ptr[i]..self.row_ptr[i + 1] {
                y[self.col_idx[p]] += self.vals[p] * xi;
            }
        }
    }

    /// Row sums A·1 in one contiguous pass. Bit-identical to
    /// [`CscMatrix::row_sums`] (same per-row addition order).
    pub fn row_sums(&self) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        self.row_sums_into(&mut y);
        y
    }

    /// [`CsrMatrix::row_sums`] into a reused buffer (resized to `rows`,
    /// keeping capacity).
    pub fn row_sums_into(&self, y: &mut Vec<f64>) {
        y.clear();
        y.resize(self.rows, 0.0);
        for (i, slot) in y.iter_mut().enumerate() {
            let mut acc = 0.0;
            for p in self.row_ptr[i]..self.row_ptr[i + 1] {
                acc += self.vals[p];
            }
            *slot = acc;
        }
    }

    /// Per-row nonzero counts — a pointer diff per row, no scatter.
    pub fn row_degrees(&self) -> Vec<usize> {
        (0..self.rows).map(|i| self.row_nnz(i)).collect()
    }

    pub fn to_dense(&self) -> DenseMatrix {
        let mut m = DenseMatrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            for p in self.row_ptr[i]..self.row_ptr[i + 1] {
                m[(i, self.col_idx[p])] += self.vals[p];
            }
        }
        m
    }
}

impl CscMatrix {
    /// Build the CSR mirror (allocating; see
    /// [`CscMatrix::to_csr_into`] for the workspace-cached variant).
    pub fn to_csr(&self) -> CsrMatrix {
        CsrMatrix::from_csc(self)
    }

    /// Build the CSR mirror into caller-owned buffers: zero heap
    /// traffic once `out`'s capacity has grown to this nnz/shape.
    ///
    /// Stable counting-sort transpose: within each CSR row, entries
    /// keep ascending column order (duplicates keep their CSC order),
    /// which is what makes the CSR kernels bit-identical to the CSC
    /// ones. No scratch needed — `row_ptr` doubles as the insertion
    /// cursor and is shifted back afterwards.
    pub fn to_csr_into(&self, out: &mut CsrMatrix) {
        out.rows = self.rows;
        out.cols = self.cols;
        out.row_ptr.clear();
        out.row_ptr.resize(self.rows + 1, 0);
        for &r in &self.row_idx {
            out.row_ptr[r + 1] += 1;
        }
        for i in 1..=self.rows {
            out.row_ptr[i] += out.row_ptr[i - 1];
        }
        let nnz = self.nnz();
        out.col_idx.clear();
        out.col_idx.resize(nnz, 0);
        out.vals.clear();
        out.vals.resize(nnz, 0.0);
        for j in 0..self.cols {
            for p in self.col_ptr[j]..self.col_ptr[j + 1] {
                let r = self.row_idx[p];
                let dst = out.row_ptr[r];
                out.col_idx[dst] = j;
                out.vals[dst] = self.vals[p];
                out.row_ptr[r] += 1;
            }
        }
        // Each cursor now sits at its row's end == the next row's
        // start; shift right to restore the start pointers.
        for i in (1..=self.rows).rev() {
            out.row_ptr[i] = out.row_ptr[i - 1];
        }
        out.row_ptr[0] = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn example() -> CscMatrix {
        // [[1, 0, 2],
        //  [0, 3, 0],
        //  [4, 0, 5]]
        CscMatrix::from_columns(
            3,
            vec![vec![(0, 1.0), (2, 4.0)], vec![(1, 3.0)], vec![(0, 2.0), (2, 5.0)]],
        )
    }

    #[test]
    fn roundtrip_preserves_dense_form() {
        let a = example();
        let csr = a.to_csr();
        assert_eq!(csr.to_dense(), a.to_dense());
        assert_eq!(csr.nnz(), a.nnz());
        assert_eq!((csr.rows, csr.cols), (a.rows, a.cols));
    }

    #[test]
    fn rows_are_in_ascending_column_order() {
        let csr = example().to_csr();
        for i in 0..csr.rows {
            let cols: Vec<usize> = csr.row(i).map(|(c, _)| c).collect();
            let mut sorted = cols.clone();
            sorted.sort_unstable();
            assert_eq!(cols, sorted, "row {i}");
        }
        assert_eq!(csr.row(0).collect::<Vec<_>>(), vec![(0, 1.0), (2, 2.0)]);
        assert_eq!(csr.row_nnz(1), 1);
    }

    #[test]
    fn matvec_bit_identical_to_csc() {
        let a = example();
        let csr = a.to_csr();
        let x = vec![1.5, -2.0, 0.25];
        let yc = a.matvec(&x);
        let yr = csr.matvec(&x);
        for (c, r) in yc.iter().zip(&yr) {
            assert_eq!(c.to_bits(), r.to_bits());
        }
    }

    #[test]
    fn t_matvec_and_row_sums_bit_identical_to_csc() {
        let a = example();
        let csr = a.to_csr();
        let x = vec![0.5, 1.0, -1.0];
        for (c, r) in a.t_matvec(&x).iter().zip(&csr.t_matvec(&x)) {
            assert_eq!(c.to_bits(), r.to_bits());
        }
        for (c, r) in a.row_sums().iter().zip(&csr.row_sums()) {
            assert_eq!(c.to_bits(), r.to_bits());
        }
        assert_eq!(a.row_degrees(), csr.row_degrees());
    }

    #[test]
    fn to_csr_into_reuses_buffers_and_matches_fresh() {
        let a = example();
        let mut out = CsrMatrix::empty();
        a.to_csr_into(&mut out);
        assert_eq!(out, a.to_csr());
        // Convert a different (smaller) matrix into the same buffer.
        let b = CscMatrix::from_supports(2, vec![vec![1], vec![0, 1]]);
        b.to_csr_into(&mut out);
        assert_eq!(out, b.to_csr());
        assert_eq!(out.rows, 2);
        assert_eq!(out.nnz(), 3);
    }

    #[test]
    fn empty_and_zero_row_matrices() {
        let empty = CscMatrix::empty().to_csr();
        assert_eq!(empty.rows, 0);
        assert_eq!(empty.row_ptr, vec![0]);

        // A matrix with an empty row and an empty column.
        let a = CscMatrix::from_columns(3, vec![vec![(0, 1.0)], vec![], vec![(2, 2.0)]]);
        let csr = a.to_csr();
        assert_eq!(csr.row_nnz(0), 1);
        assert_eq!(csr.row_nnz(1), 0);
        assert_eq!(csr.row_nnz(2), 1);
        assert_eq!(csr.to_dense(), a.to_dense());
    }

    #[test]
    fn duplicate_entries_preserved() {
        // Duplicate (row, col) entries must survive with multiplicity,
        // in the same order CSC stores them (the transpose is stable).
        let a = CscMatrix::from_columns(2, vec![vec![(0, 1.0), (0, 2.0)], vec![(1, 3.0)]]);
        let csr = a.to_csr();
        assert_eq!(csr.nnz(), 3);
        let csc_col0_vals: Vec<f64> = a.col(0).map(|(_, v)| v).collect();
        let csr_row0_vals: Vec<f64> = csr.row(0).map(|(_, v)| v).collect();
        assert_eq!(csr_row0_vals, csc_col0_vals);
        assert!(csr.row(0).all(|(c, _)| c == 0));
        assert_eq!(csr.row_sums(), a.row_sums());
    }
}
