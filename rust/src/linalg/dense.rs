//! Dense row-major f64 matrices — used for small-k cross-validation
//! (Cholesky solves, exhaustive adversaries) and for test oracles.
//! The hot decoding paths use `sparse::CscMatrix` instead.

use std::ops::{Index, IndexMut};

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseMatrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl DenseMatrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        DenseMatrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Self::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        assert!(rows.iter().all(|x| x.len() == c), "ragged rows");
        DenseMatrix { rows: r, cols: c, data: rows.concat() }
    }

    pub fn identity(n: usize) -> Self {
        Self::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// y = A x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            let row = &self.data[i * self.cols..(i + 1) * self.cols];
            y[i] = row.iter().zip(x).map(|(a, b)| a * b).sum();
        }
        y
    }

    /// y = A^T x.
    pub fn t_matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        let mut y = vec![0.0; self.cols];
        for i in 0..self.rows {
            let row = &self.data[i * self.cols..(i + 1) * self.cols];
            let xi = x[i];
            for (yj, a) in y.iter_mut().zip(row) {
                *yj += a * xi;
            }
        }
        y
    }

    pub fn matmul(&self, other: &DenseMatrix) -> DenseMatrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = DenseMatrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] += a * other[(k, j)];
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> DenseMatrix {
        DenseMatrix::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    /// A^T A (Gram matrix), used by the Cholesky decoder.
    pub fn gram(&self) -> DenseMatrix {
        let mut g = DenseMatrix::zeros(self.cols, self.cols);
        for i in 0..self.rows {
            let row = &self.data[i * self.cols..(i + 1) * self.cols];
            for a in 0..self.cols {
                let ra = row[a];
                if ra == 0.0 {
                    continue;
                }
                for b in a..self.cols {
                    g[(a, b)] += ra * row[b];
                }
            }
        }
        for a in 0..self.cols {
            for b in 0..a {
                g[(a, b)] = g[(b, a)];
            }
        }
        g
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    pub fn frobenius_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }
}

impl Index<(usize, usize)> for DenseMatrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for DenseMatrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

// ------------------------------------------------------------- vector ops

pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

pub fn norm2_sq(a: &[f64]) -> f64 {
    dot(a, a)
}

/// y += alpha * x
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

pub fn scale(alpha: f64, x: &mut [f64]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_identity() {
        let m = DenseMatrix::identity(4);
        let x = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(m.matvec(&x), x);
    }

    #[test]
    fn matvec_and_transpose_agree() {
        let m = DenseMatrix::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        let x = vec![1.0, -1.0];
        assert_eq!(m.t_matvec(&x), m.transpose().matvec(&x));
    }

    #[test]
    fn matmul_small() {
        let a = DenseMatrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = DenseMatrix::from_rows(&[vec![0.0, 1.0], vec![1.0, 0.0]]);
        let c = a.matmul(&b);
        assert_eq!(c, DenseMatrix::from_rows(&[vec![2.0, 1.0], vec![4.0, 3.0]]));
    }

    #[test]
    fn gram_matches_explicit() {
        let a = DenseMatrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        let g = a.gram();
        let expect = a.transpose().matmul(&a);
        for i in 0..2 {
            for j in 0..2 {
                assert!((g[(i, j)] - expect[(i, j)]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn vector_helpers() {
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[3.0, 4.0], &mut y);
        assert_eq!(y, vec![7.0, 9.0]);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
        scale(0.5, &mut y);
        assert_eq!(y, vec![3.5, 4.5]);
    }

    #[test]
    #[should_panic]
    fn matmul_shape_mismatch_panics() {
        let a = DenseMatrix::zeros(2, 3);
        let b = DenseMatrix::zeros(2, 3);
        let _ = a.matmul(&b);
    }
}
