//! SIMD-friendly blocked kernels — manual 4-lane (`f64x4`-style)
//! blocking for the dense reductions in the LSQR inner loop and the
//! row reductions of the CSR fast path.
//!
//! The portable default uses no `std::simd` / intrinsics (the crate
//! builds on stable with no deps); instead every reduction runs 4
//! independent accumulators so LLVM can keep them in one vector
//! register, plus a scalar tail. Under `--features simd` on x86_64 the
//! dense kernels additionally get an explicit AVX2 tier, dispatched at
//! runtime via [`super::tier::simd_tier`]: one `__m256d` holds the same
//! 4 accumulators, the horizontal combine replays the exact portable
//! grouping, and the tail stays scalar — so the AVX2 tier is
//! **bit-identical to the portable kernels on arbitrary data** (not
//! just integer data; no FMA anywhere). The gather-shaped reductions
//! (`sum` is cheap, `masked_row_sum` is index-indirect) keep only the
//! portable form — this module tops out at AVX2; the AVX-512 tier
//! lives in the panel kernels where the lane-strided layout earns it.
//!
//! **Blocking convention** (shared by every kernel here, and the
//! contract the parity suite pins):
//! * lane width [`LANES`] = 4, accumulators a0..a3 over indices
//!   `4c + lane`;
//! * lanes combine as `(a0 + a1) + (a2 + a3)`, then `+ tail` last;
//! * elementwise kernels (`axpy`, `scaled_sub`, `update_x_w`) are
//!   bit-identical to their scalar loops (no reassociation — unrolling
//!   an elementwise op does not change its arithmetic);
//! * reduction kernels (`dot`, `norm2_sq`, `sum`, `masked_row_sum`,
//!   `diff_norm2_sq`) reassociate the sum, so on arbitrary f64 data
//!   they agree with the scalar order only to rounding — but on
//!   integer-valued data (boolean assignment matrices, coverage
//!   counts < 2^53) every grouping is exact, so blocked == scalar
//!   bit-for-bit. `tests/linalg_parity.rs` pins both regimes.

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
use super::tier::{simd_tier, SimdTier};

/// Lane width of the manual blocking.
pub const LANES: usize = 4;

/// AVX2 tier of [`dot`]: the 4 portable accumulators live in one
/// `__m256d` (register lane j accumulates indices `4c + j`), combined
/// with the portable grouping `((s0+s1)+(s2+s3)) + tail` — bit-identical
/// to the portable kernel on arbitrary data.
///
/// # Safety
/// The CPU must support AVX2 (callers dispatch on [`simd_tier`]).
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[target_feature(enable = "avx2")]
unsafe fn dot_avx2(a: &[f64], b: &[f64]) -> f64 {
    use std::arch::x86_64::{_mm256_add_pd, _mm256_loadu_pd, _mm256_mul_pd, _mm256_setzero_pd, _mm256_storeu_pd};
    let n = a.len();
    let q = n - n % LANES;
    let mut acc = _mm256_setzero_pd();
    let mut i = 0;
    while i < q {
        let va = _mm256_loadu_pd(a.as_ptr().add(i));
        let vb = _mm256_loadu_pd(b.as_ptr().add(i));
        acc = _mm256_add_pd(acc, _mm256_mul_pd(va, vb));
        i += LANES;
    }
    let mut s = [0.0f64; LANES];
    _mm256_storeu_pd(s.as_mut_ptr(), acc);
    let mut tail = 0.0;
    for j in q..n {
        tail += a[j] * b[j];
    }
    ((s[0] + s[1]) + (s[2] + s[3])) + tail
}

/// Blocked dot product Σ a_i b_i.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd_tier() >= SimdTier::Avx2 {
        // SAFETY: dispatch is guarded by runtime avx2 detection.
        return unsafe { dot_avx2(a, b) };
    }
    let n = a.len();
    let q = n - n % LANES;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0, 0.0, 0.0);
    let mut i = 0;
    while i < q {
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
        i += LANES;
    }
    let mut tail = 0.0;
    for j in q..n {
        tail += a[j] * b[j];
    }
    ((s0 + s1) + (s2 + s3)) + tail
}

/// Blocked Σ a_i² (squared 2-norm).
#[inline]
pub fn norm2_sq(a: &[f64]) -> f64 {
    dot(a, a)
}

/// Blocked 2-norm.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    norm2_sq(a).sqrt()
}

/// Blocked Σ a_i.
#[inline]
pub fn sum(a: &[f64]) -> f64 {
    let n = a.len();
    let q = n - n % LANES;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0, 0.0, 0.0);
    let mut i = 0;
    while i < q {
        s0 += a[i];
        s1 += a[i + 1];
        s2 += a[i + 2];
        s3 += a[i + 3];
        i += LANES;
    }
    let mut tail = 0.0;
    for j in q..n {
        tail += a[j];
    }
    ((s0 + s1) + (s2 + s3)) + tail
}

/// AVX2 tier of [`diff_norm2_sq`]: same accumulator layout and combine
/// as [`dot_avx2`], differences computed per lane — bit-identical to
/// the portable kernel on arbitrary data.
///
/// # Safety
/// The CPU must support AVX2 (callers dispatch on [`simd_tier`]).
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[target_feature(enable = "avx2")]
unsafe fn diff_norm2_sq_avx2(a: &[f64], b: &[f64]) -> f64 {
    use std::arch::x86_64::{_mm256_add_pd, _mm256_loadu_pd, _mm256_mul_pd, _mm256_setzero_pd, _mm256_storeu_pd, _mm256_sub_pd};
    let n = a.len();
    let q = n - n % LANES;
    let mut acc = _mm256_setzero_pd();
    let mut i = 0;
    while i < q {
        let d = _mm256_sub_pd(_mm256_loadu_pd(a.as_ptr().add(i)), _mm256_loadu_pd(b.as_ptr().add(i)));
        acc = _mm256_add_pd(acc, _mm256_mul_pd(d, d));
        i += LANES;
    }
    let mut s = [0.0f64; LANES];
    _mm256_storeu_pd(s.as_mut_ptr(), acc);
    let mut tail = 0.0;
    for j in q..n {
        let d = a[j] - b[j];
        tail += d * d;
    }
    ((s[0] + s[1]) + (s[2] + s[3])) + tail
}

/// Blocked Σ (a_i − b_i)² — the LSQR true-residual recomputation.
#[inline]
pub fn diff_norm2_sq(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd_tier() >= SimdTier::Avx2 {
        // SAFETY: dispatch is guarded by runtime avx2 detection.
        return unsafe { diff_norm2_sq_avx2(a, b) };
    }
    let n = a.len();
    let q = n - n % LANES;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0, 0.0, 0.0);
    let mut i = 0;
    while i < q {
        let d0 = a[i] - b[i];
        let d1 = a[i + 1] - b[i + 1];
        let d2 = a[i + 2] - b[i + 2];
        let d3 = a[i + 3] - b[i + 3];
        s0 += d0 * d0;
        s1 += d1 * d1;
        s2 += d2 * d2;
        s3 += d3 * d3;
        i += LANES;
    }
    let mut tail = 0.0;
    for j in q..n {
        let d = a[j] - b[j];
        tail += d * d;
    }
    ((s0 + s1) + (s2 + s3)) + tail
}

/// Gather-multiply row reduction for the CSR fast path:
/// `Σ_p vals[p] · count[cols[p]]`. `count` is the per-column selection
/// multiplicity (0 for stragglers). Exact — identical to any other
/// accumulation order — whenever the products are integers (boolean
/// G), which is every code the paper constructs.
#[inline]
pub fn masked_row_sum(vals: &[f64], cols: &[usize], count: &[u32]) -> f64 {
    debug_assert_eq!(vals.len(), cols.len());
    let n = vals.len();
    let q = n - n % LANES;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0, 0.0, 0.0);
    let mut i = 0;
    while i < q {
        s0 += vals[i] * count[cols[i]] as f64;
        s1 += vals[i + 1] * count[cols[i + 1]] as f64;
        s2 += vals[i + 2] * count[cols[i + 2]] as f64;
        s3 += vals[i + 3] * count[cols[i + 3]] as f64;
        i += LANES;
    }
    let mut tail = 0.0;
    for j in q..n {
        tail += vals[j] * count[cols[j]] as f64;
    }
    ((s0 + s1) + (s2 + s3)) + tail
}

// --------------------------------------------- elementwise (bit-transparent)

/// AVX2 tier of [`axpy`]. Elementwise mul/add per lane, no FMA —
/// bit-identical to the scalar loop.
///
/// # Safety
/// The CPU must support AVX2 (callers dispatch on [`simd_tier`]).
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[target_feature(enable = "avx2")]
unsafe fn axpy_avx2(alpha: f64, x: &[f64], y: &mut [f64]) {
    use std::arch::x86_64::{_mm256_add_pd, _mm256_loadu_pd, _mm256_mul_pd, _mm256_set1_pd, _mm256_storeu_pd};
    let n = x.len();
    let q = n - n % LANES;
    let va = _mm256_set1_pd(alpha);
    let mut i = 0;
    while i < q {
        let vx = _mm256_loadu_pd(x.as_ptr().add(i));
        let vy = _mm256_loadu_pd(y.as_ptr().add(i));
        _mm256_storeu_pd(y.as_mut_ptr().add(i), _mm256_add_pd(vy, _mm256_mul_pd(va, vx)));
        i += LANES;
    }
    for j in q..n {
        y[j] += alpha * x[j];
    }
}

/// y += α·x, 4-unrolled. Elementwise: bit-identical to the scalar loop.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd_tier() >= SimdTier::Avx2 {
        // SAFETY: dispatch is guarded by runtime avx2 detection.
        return unsafe { axpy_avx2(alpha, x, y) };
    }
    let n = x.len();
    let q = n - n % LANES;
    let mut i = 0;
    while i < q {
        y[i] += alpha * x[i];
        y[i + 1] += alpha * x[i + 1];
        y[i + 2] += alpha * x[i + 2];
        y[i + 3] += alpha * x[i + 3];
        i += LANES;
    }
    for j in q..n {
        y[j] += alpha * x[j];
    }
}

/// AVX2 tier of [`scaled_sub`]. Elementwise, no FMA — bit-identical to
/// the scalar loop.
///
/// # Safety
/// The CPU must support AVX2 (callers dispatch on [`simd_tier`]).
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[target_feature(enable = "avx2")]
unsafe fn scaled_sub_avx2(x: &[f64], alpha: f64, y: &mut [f64]) {
    use std::arch::x86_64::{_mm256_loadu_pd, _mm256_mul_pd, _mm256_set1_pd, _mm256_storeu_pd, _mm256_sub_pd};
    let n = x.len();
    let q = n - n % LANES;
    let va = _mm256_set1_pd(alpha);
    let mut i = 0;
    while i < q {
        let vx = _mm256_loadu_pd(x.as_ptr().add(i));
        let vy = _mm256_loadu_pd(y.as_ptr().add(i));
        _mm256_storeu_pd(y.as_mut_ptr().add(i), _mm256_sub_pd(vx, _mm256_mul_pd(va, vy)));
        i += LANES;
    }
    for j in q..n {
        y[j] = x[j] - alpha * y[j];
    }
}

/// y ← x − α·y, 4-unrolled (the LSQR bidiagonalization refresh
/// `u = A v − α u`). Elementwise: bit-identical to the scalar loop.
#[inline]
pub fn scaled_sub(x: &[f64], alpha: f64, y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd_tier() >= SimdTier::Avx2 {
        // SAFETY: dispatch is guarded by runtime avx2 detection.
        return unsafe { scaled_sub_avx2(x, alpha, y) };
    }
    let n = x.len();
    let q = n - n % LANES;
    let mut i = 0;
    while i < q {
        y[i] = x[i] - alpha * y[i];
        y[i + 1] = x[i + 1] - alpha * y[i + 1];
        y[i + 2] = x[i + 2] - alpha * y[i + 2];
        y[i + 3] = x[i + 3] - alpha * y[i + 3];
        i += LANES;
    }
    for j in q..n {
        y[j] = x[j] - alpha * y[j];
    }
}

/// AVX2 tier of [`update_x_w`]: the old `w` quad is loaded once and
/// used for both updates, matching the scalar loop's read-before-write
/// order. Elementwise, no FMA — bit-identical.
///
/// # Safety
/// The CPU must support AVX2 (callers dispatch on [`simd_tier`]).
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[target_feature(enable = "avx2")]
unsafe fn update_x_w_avx2(x: &mut [f64], w: &mut [f64], v: &[f64], t1: f64, t2: f64) {
    use std::arch::x86_64::{_mm256_add_pd, _mm256_loadu_pd, _mm256_mul_pd, _mm256_set1_pd, _mm256_storeu_pd};
    let n = x.len();
    let q = n - n % LANES;
    let vt1 = _mm256_set1_pd(t1);
    let vt2 = _mm256_set1_pd(t2);
    let mut i = 0;
    while i < q {
        let vw = _mm256_loadu_pd(w.as_ptr().add(i));
        let vx = _mm256_loadu_pd(x.as_ptr().add(i));
        let vv = _mm256_loadu_pd(v.as_ptr().add(i));
        _mm256_storeu_pd(x.as_mut_ptr().add(i), _mm256_add_pd(vx, _mm256_mul_pd(vt1, vw)));
        _mm256_storeu_pd(w.as_mut_ptr().add(i), _mm256_add_pd(vv, _mm256_mul_pd(vt2, vw)));
        i += LANES;
    }
    for j in q..n {
        x[j] += t1 * w[j];
        w[j] = v[j] + t2 * w[j];
    }
}

/// The fused LSQR solution/search-direction update:
/// x += t1·w; w ← v + t2·w (old w used for both, per element).
/// Elementwise: bit-identical to the scalar loop.
#[inline]
pub fn update_x_w(x: &mut [f64], w: &mut [f64], v: &[f64], t1: f64, t2: f64) {
    debug_assert_eq!(x.len(), w.len());
    debug_assert_eq!(x.len(), v.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd_tier() >= SimdTier::Avx2 {
        // SAFETY: dispatch is guarded by runtime avx2 detection.
        return unsafe { update_x_w_avx2(x, w, v, t1, t2) };
    }
    let n = x.len();
    let q = n - n % LANES;
    let mut i = 0;
    while i < q {
        x[i] += t1 * w[i];
        w[i] = v[i] + t2 * w[i];
        x[i + 1] += t1 * w[i + 1];
        w[i + 1] = v[i + 1] + t2 * w[i + 1];
        x[i + 2] += t1 * w[i + 2];
        w[i + 2] = v[i + 2] + t2 * w[i + 2];
        x[i + 3] += t1 * w[i + 3];
        w[i + 3] = v[i + 3] + t2 * w[i + 3];
        i += LANES;
    }
    for j in q..n {
        x[j] += t1 * w[j];
        w[j] = v[j] + t2 * w[j];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_vec(rng: &mut Rng, n: usize) -> Vec<f64> {
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn dot_matches_scalar_within_rounding() {
        let mut rng = Rng::new(1);
        for n in [0, 1, 3, 4, 5, 7, 8, 64, 1001] {
            let a = random_vec(&mut rng, n);
            let b = random_vec(&mut rng, n);
            let scalar: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let blocked = dot(&a, &b);
            let scale: f64 = a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum::<f64>().max(1.0);
            assert!((blocked - scalar).abs() <= 1e-12 * scale, "n={n}: {blocked} vs {scalar}");
        }
    }

    #[test]
    fn reductions_exact_on_integer_data() {
        // Integer-valued f64 sums are exact under any grouping, so the
        // blocked kernels must match the scalar order bit-for-bit.
        let mut rng = Rng::new(2);
        for n in [1, 5, 16, 129] {
            let a: Vec<f64> = (0..n).map(|_| rng.usize(100) as f64).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.usize(100) as f64).collect();
            let scalar_dot: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert_eq!(dot(&a, &b).to_bits(), scalar_dot.to_bits());
            let scalar_sum: f64 = a.iter().sum();
            assert_eq!(sum(&a).to_bits(), scalar_sum.to_bits());
        }
    }

    #[test]
    fn elementwise_kernels_bit_identical_to_scalar() {
        let mut rng = Rng::new(3);
        for n in [0, 1, 2, 4, 6, 9, 33] {
            let x = random_vec(&mut rng, n);
            let v = random_vec(&mut rng, n);
            let y0 = random_vec(&mut rng, n);
            let (alpha, t1, t2) = (rng.normal(), rng.normal(), rng.normal());

            let mut y_scalar = y0.clone();
            for j in 0..n {
                y_scalar[j] += alpha * x[j];
            }
            let mut y_blocked = y0.clone();
            axpy(alpha, &x, &mut y_blocked);
            assert_eq!(y_scalar, y_blocked, "axpy n={n}");

            let mut u_scalar = y0.clone();
            for j in 0..n {
                u_scalar[j] = x[j] - alpha * u_scalar[j];
            }
            let mut u_blocked = y0.clone();
            scaled_sub(&x, alpha, &mut u_blocked);
            assert_eq!(u_scalar, u_blocked, "scaled_sub n={n}");

            let (mut xs, mut ws) = (y0.clone(), x.clone());
            for j in 0..n {
                xs[j] += t1 * ws[j];
                ws[j] = v[j] + t2 * ws[j];
            }
            let (mut xb, mut wb) = (y0.clone(), x.clone());
            update_x_w(&mut xb, &mut wb, &v, t1, t2);
            assert_eq!(xs, xb, "update_x_w x n={n}");
            assert_eq!(ws, wb, "update_x_w w n={n}");
        }
    }

    #[test]
    fn masked_row_sum_counts_boolean_exactly() {
        // Boolean values + integer counts: the reduction is exact.
        let vals = vec![1.0; 11];
        let cols: Vec<usize> = (0..11).collect();
        let mut count = vec![0u32; 11];
        for j in [0, 2, 3, 7, 10, 10] {
            count[j] += 1;
        }
        // note: col 10 has multiplicity 2 via the repeated index above
        let expect: f64 = cols.iter().map(|&c| count[c] as f64).sum();
        assert_eq!(masked_row_sum(&vals, &cols, &count).to_bits(), expect.to_bits());
        assert_eq!(masked_row_sum(&vals, &cols, &count), 6.0);
    }

    #[test]
    fn diff_norm2_sq_matches_naive() {
        let mut rng = Rng::new(4);
        let a = random_vec(&mut rng, 37);
        let b = random_vec(&mut rng, 37);
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
        assert!((diff_norm2_sq(&a, &b) - naive).abs() <= 1e-12 * naive.max(1.0));
    }

    #[test]
    fn empty_inputs_are_zero() {
        assert_eq!(dot(&[], &[]), 0.0);
        assert_eq!(sum(&[]), 0.0);
        assert_eq!(norm2(&[]), 0.0);
        assert_eq!(diff_norm2_sq(&[], &[]), 0.0);
        assert_eq!(masked_row_sum(&[], &[], &[]), 0.0);
    }
}
