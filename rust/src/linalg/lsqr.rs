//! LSQR (Paige & Saunders 1982) — the optimal decoder's solver.
//!
//! Solves min_x ||A x - b||_2 using only matvec / t_matvec, so it runs
//! directly on the sparse non-straggler matrix A without forming A^T A.
//! This matters for the paper's Algorithm 2: A is k x r, sparse (s
//! entries per column) and often rank-deficient (FRC has duplicate
//! columns); LSQR converges to the minimum-norm least-squares solution.
//!
//! Two entry points:
//! * [`lsqr`] — the allocating reference path (fresh vectors per solve).
//! * [`lsqr_with`] — the hot-path variant: every per-solve vector lives
//!   in a caller-owned [`LsqrWorkspace`] reused across trials, and an
//!   optional warm-start iterate `x0` turns the solve into a correction
//!   solve `min_dx ||A dx - (b - A x0)||`. With `x0 = None` the
//!   arithmetic is operation-for-operation identical to [`lsqr`], so
//!   the two paths produce bit-identical results (pinned by tests).
//!
//! Both entry points run their dense inner-loop arithmetic through the
//! [`blocked`](super::blocked) 4-lane kernels — the same kernels in
//! both, so the lsqr/lsqr_with bit-parity above is unaffected by the
//! blocking (reductions reassociate identically in the two paths).

use super::blocked;
use super::sparse::CscMatrix;

/// Convergence report for an LSQR run.
#[derive(Clone, Debug)]
pub struct LsqrResult {
    pub x: Vec<f64>,
    /// ||A x - b||_2 at the returned iterate.
    pub residual_norm: f64,
    pub iterations: usize,
    pub converged: bool,
}

/// Options for `lsqr`.
#[derive(Clone, Debug)]
pub struct LsqrOptions {
    pub atol: f64,
    pub btol: f64,
    pub max_iter: usize,
}

impl Default for LsqrOptions {
    fn default() -> Self {
        LsqrOptions { atol: 1e-12, btol: 1e-12, max_iter: 0 }
    }
}

/// min_x ||A x - b||. `max_iter = 0` defaults to 4 * max(rows, cols).
pub fn lsqr(a: &CscMatrix, b: &[f64], opts: &LsqrOptions) -> LsqrResult {
    let (m, n) = (a.rows, a.cols);
    assert_eq!(b.len(), m);
    let max_iter = if opts.max_iter == 0 { 4 * m.max(n) } else { opts.max_iter };

    // Golub-Kahan bidiagonalization state.
    let mut u = b.to_vec();
    let mut beta = blocked::norm2(&u);
    let mut x = vec![0.0; n];
    if beta == 0.0 {
        return LsqrResult { x, residual_norm: 0.0, iterations: 0, converged: true };
    }
    for ui in u.iter_mut() {
        *ui /= beta;
    }
    let mut v = a.t_matvec(&u);
    let mut alpha = blocked::norm2(&v);
    if alpha == 0.0 {
        // b orthogonal to range(A): x = 0 is optimal.
        return LsqrResult { x, residual_norm: beta, iterations: 0, converged: true };
    }
    for vi in v.iter_mut() {
        *vi /= alpha;
    }

    let mut w = v.clone();
    let mut phi_bar = beta;
    let mut rho_bar = alpha;
    let b_norm = beta;
    let mut a_norm_sq = 0.0; // running estimate of ||A||_F^2 over the Krylov basis

    let mut iterations = 0;
    let mut converged = false;

    // Scratch buffers reused across iterations (perf: allocation-free
    // inner loop — see EXPERIMENTS.md §Perf).
    let mut av = vec![0.0; m];
    let mut atu = vec![0.0; n];

    for it in 1..=max_iter {
        iterations = it;

        // u = A v - alpha u; beta = ||u||
        a.matvec_into(&v, &mut av);
        blocked::scaled_sub(&av, alpha, &mut u);
        beta = blocked::norm2(&u);
        if beta > 0.0 {
            for ui in u.iter_mut() {
                *ui /= beta;
            }
        }

        // v = A^T u - beta v; alpha = ||v||
        a.t_matvec_into(&u, &mut atu);
        blocked::scaled_sub(&atu, beta, &mut v);
        alpha = blocked::norm2(&v);
        if alpha > 0.0 {
            for vi in v.iter_mut() {
                *vi /= alpha;
            }
        }

        a_norm_sq += alpha * alpha + beta * beta;

        // Givens rotation to eliminate beta from the bidiagonal system.
        let rho = (rho_bar * rho_bar + beta * beta).sqrt();
        let c = rho_bar / rho;
        let s = beta / rho;
        let theta = s * alpha;
        rho_bar = -c * alpha;
        let phi = c * phi_bar;
        phi_bar *= s;

        // Update x and the search direction w.
        let t1 = phi / rho;
        let t2 = -theta / rho;
        blocked::update_x_w(&mut x, &mut w, &v, t1, t2);

        // Stopping rules (Paige-Saunders criteria 1 & 2).
        let res = phi_bar; // ||A x - b|| for the current iterate
        let a_norm = a_norm_sq.sqrt();
        // ||A^T r|| estimate:
        let atr = phi_bar * alpha * c.abs();
        if res <= opts.btol * b_norm + opts.atol * a_norm * blocked::norm2(&x) {
            converged = true;
            break;
        }
        if a_norm > 0.0 && res > 0.0 && atr / (a_norm * res) <= opts.atol {
            converged = true;
            break;
        }
        if alpha == 0.0 {
            converged = true;
            break;
        }
    }

    // Recompute the true residual (phi_bar is an estimate) — via the
    // same blocked kernel `lsqr_with` uses, preserving their bit-parity.
    let ax = a.matvec(&x);
    let residual_norm = blocked::diff_norm2_sq(b, &ax).sqrt();
    LsqrResult { x, residual_norm, iterations, converged }
}

/// Reusable scratch for [`lsqr_with`]: the Golub-Kahan vectors (u, v,
/// w), the solution x, and the two matvec buffers. `clear + resize`
/// keeps capacity, so a workspace reused across same-shaped solves does
/// zero heap allocation after the first solve.
#[derive(Clone, Debug, Default)]
pub struct LsqrWorkspace {
    u: Vec<f64>,
    v: Vec<f64>,
    w: Vec<f64>,
    x: Vec<f64>,
    av: Vec<f64>,
    atu: Vec<f64>,
}

impl LsqrWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// The solution vector of the most recent [`lsqr_with`] call.
    pub fn x(&self) -> &[f64] {
        &self.x
    }
}

/// Convergence report for [`lsqr_with`] — like [`LsqrResult`] but the
/// solution stays in the workspace ([`LsqrWorkspace::x`]), so the hot
/// path returns without allocating.
#[derive(Clone, Copy, Debug)]
pub struct LsqrSummary {
    /// ||A x - b||_2 at the returned iterate.
    pub residual_norm: f64,
    pub iterations: usize,
    pub converged: bool,
}

/// min_x ||A x - b|| with workspace-owned vectors and optional warm
/// start. `x0 = Some(v)` solves for the correction `dx` against the
/// deflated rhs `b - A x0` and returns `x = x0 + dx` in `ws.x` — at the
/// paper's figure points the one-step weights ρ·1_r are a natural x0,
/// shared by every trial at the point. `x0 = None` reproduces [`lsqr`]
/// bit-for-bit.
pub fn lsqr_with(
    a: &CscMatrix,
    b: &[f64],
    opts: &LsqrOptions,
    x0: Option<&[f64]>,
    ws: &mut LsqrWorkspace,
) -> LsqrSummary {
    let (m, n) = (a.rows, a.cols);
    assert_eq!(b.len(), m);
    let max_iter = if opts.max_iter == 0 { 4 * m.max(n) } else { opts.max_iter };

    ws.x.clear();
    ws.x.resize(n, 0.0);
    ws.v.clear();
    ws.v.resize(n, 0.0);
    ws.w.clear();
    ws.w.resize(n, 0.0);
    ws.av.clear();
    ws.av.resize(m, 0.0);
    ws.atu.clear();
    ws.atu.resize(n, 0.0);

    // u = b - A x0 (just b when cold: identical arithmetic to `lsqr`).
    ws.u.clear();
    ws.u.extend_from_slice(b);
    if let Some(x0) = x0 {
        assert_eq!(x0.len(), n, "warm-start length != cols");
        a.matvec_into(x0, &mut ws.av);
        for i in 0..m {
            ws.u[i] -= ws.av[i];
        }
    }

    let mut beta = blocked::norm2(&ws.u);
    if beta == 0.0 {
        // b (or the deflated rhs) already reproduced exactly: x = x0.
        if let Some(x0) = x0 {
            ws.x.copy_from_slice(x0);
        }
        return LsqrSummary { residual_norm: 0.0, iterations: 0, converged: true };
    }
    for ui in ws.u.iter_mut() {
        *ui /= beta;
    }
    a.t_matvec_into(&ws.u, &mut ws.v);
    let mut alpha = blocked::norm2(&ws.v);
    if alpha == 0.0 {
        // rhs orthogonal to range(A): dx = 0 is optimal.
        if let Some(x0) = x0 {
            ws.x.copy_from_slice(x0);
        }
        return LsqrSummary { residual_norm: beta, iterations: 0, converged: true };
    }
    for vi in ws.v.iter_mut() {
        *vi /= alpha;
    }

    ws.w.copy_from_slice(&ws.v);
    let mut phi_bar = beta;
    let mut rho_bar = alpha;
    let b_norm = beta;
    let mut a_norm_sq = 0.0;

    let mut iterations = 0;
    let mut converged = false;

    for it in 1..=max_iter {
        iterations = it;

        // u = A v - alpha u; beta = ||u||
        a.matvec_into(&ws.v, &mut ws.av);
        blocked::scaled_sub(&ws.av, alpha, &mut ws.u);
        beta = blocked::norm2(&ws.u);
        if beta > 0.0 {
            for ui in ws.u.iter_mut() {
                *ui /= beta;
            }
        }

        // v = A^T u - beta v; alpha = ||v||
        a.t_matvec_into(&ws.u, &mut ws.atu);
        blocked::scaled_sub(&ws.atu, beta, &mut ws.v);
        alpha = blocked::norm2(&ws.v);
        if alpha > 0.0 {
            for vi in ws.v.iter_mut() {
                *vi /= alpha;
            }
        }

        a_norm_sq += alpha * alpha + beta * beta;

        // Givens rotation to eliminate beta from the bidiagonal system.
        let rho = (rho_bar * rho_bar + beta * beta).sqrt();
        let c = rho_bar / rho;
        let s = beta / rho;
        let theta = s * alpha;
        rho_bar = -c * alpha;
        let phi = c * phi_bar;
        phi_bar *= s;

        // Update x and the search direction w.
        let t1 = phi / rho;
        let t2 = -theta / rho;
        blocked::update_x_w(&mut ws.x, &mut ws.w, &ws.v, t1, t2);

        // Stopping rules (Paige-Saunders criteria 1 & 2).
        let res = phi_bar;
        let a_norm = a_norm_sq.sqrt();
        let atr = phi_bar * alpha * c.abs();
        if res <= opts.btol * b_norm + opts.atol * a_norm * blocked::norm2(&ws.x) {
            converged = true;
            break;
        }
        if a_norm > 0.0 && res > 0.0 && atr / (a_norm * res) <= opts.atol {
            converged = true;
            break;
        }
        if alpha == 0.0 {
            converged = true;
            break;
        }
    }

    // Fold the warm start back in, then recompute the true residual
    // (phi_bar is an estimate) without allocating.
    if let Some(x0) = x0 {
        for j in 0..n {
            ws.x[j] += x0[j];
        }
    }
    a.matvec_into(&ws.x, &mut ws.av);
    let residual_norm = blocked::diff_norm2_sq(b, &ws.av).sqrt();
    LsqrSummary { residual_norm, iterations, converged }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense::norm2;

    fn csc(rows: usize, cols: Vec<Vec<(usize, f64)>>) -> CscMatrix {
        CscMatrix::from_columns(rows, cols)
    }

    #[test]
    fn solves_square_system_exactly() {
        // A = [[2, 1], [1, 3]], b = [5, 10] -> x = [1, 3]
        let a = csc(2, vec![vec![(0, 2.0), (1, 1.0)], vec![(0, 1.0), (1, 3.0)]]);
        let r = lsqr(&a, &[5.0, 10.0], &LsqrOptions::default());
        assert!(r.residual_norm < 1e-9, "residual {}", r.residual_norm);
        assert!((r.x[0] - 1.0).abs() < 1e-8 && (r.x[1] - 3.0).abs() < 1e-8);
    }

    #[test]
    fn overdetermined_least_squares() {
        // A = [[1],[1],[1]], b = [1, 2, 3] -> x = 2, residual^2 = 2
        let a = csc(3, vec![vec![(0, 1.0), (1, 1.0), (2, 1.0)]]);
        let r = lsqr(&a, &[1.0, 2.0, 3.0], &LsqrOptions::default());
        assert!((r.x[0] - 2.0).abs() < 1e-10);
        assert!((r.residual_norm - 2.0_f64.sqrt()).abs() < 1e-10);
    }

    #[test]
    fn rank_deficient_duplicate_columns() {
        // Two identical columns (the FRC case): minimum-norm solution
        // splits the weight, residual is still the projection error.
        let a = csc(2, vec![vec![(0, 1.0)], vec![(0, 1.0)]]);
        let r = lsqr(&a, &[1.0, 1.0], &LsqrOptions::default());
        // err(A) = ||proj_residual||^2 = 1 (second coordinate unreachable)
        assert!((r.residual_norm - 1.0).abs() < 1e-10, "residual {}", r.residual_norm);
        assert!((r.x[0] + r.x[1] - 1.0).abs() < 1e-8);
    }

    #[test]
    fn zero_rhs() {
        let a = csc(2, vec![vec![(0, 1.0)], vec![(1, 1.0)]]);
        let r = lsqr(&a, &[0.0, 0.0], &LsqrOptions::default());
        assert_eq!(r.x, vec![0.0, 0.0]);
        assert_eq!(r.residual_norm, 0.0);
    }

    #[test]
    fn b_orthogonal_to_range() {
        // A's range is span(e0); b = e1.
        let a = csc(2, vec![vec![(0, 1.0)]]);
        let r = lsqr(&a, &[0.0, 1.0], &LsqrOptions::default());
        assert!(norm2(&r.x) < 1e-12);
        assert!((r.residual_norm - 1.0).abs() < 1e-12);
    }

    #[test]
    fn lsqr_with_cold_is_bit_identical_to_lsqr() {
        use crate::util::Rng;
        let mut rng = Rng::new(11);
        let mut ws = LsqrWorkspace::new();
        for trial in 0..20 {
            let (m, n) = (12 + trial % 5, 7);
            let cols: Vec<Vec<(usize, f64)>> = (0..n)
                .map(|_| (0..m).filter(|_| rng.f64() < 0.4).map(|i| (i, rng.normal())).collect())
                .collect();
            let a = csc(m, cols);
            let b: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let reference = lsqr(&a, &b, &LsqrOptions::default());
            let summary = lsqr_with(&a, &b, &LsqrOptions::default(), None, &mut ws);
            assert_eq!(
                summary.residual_norm.to_bits(),
                reference.residual_norm.to_bits(),
                "trial {trial}: {} vs {}",
                summary.residual_norm,
                reference.residual_norm
            );
            assert_eq!(summary.iterations, reference.iterations);
            assert_eq!(summary.converged, reference.converged);
            assert_eq!(ws.x(), &reference.x[..], "trial {trial}");
        }
    }

    #[test]
    fn warm_start_reaches_same_residual() {
        use crate::util::Rng;
        let mut rng = Rng::new(12);
        let (m, n) = (25, 10);
        let cols: Vec<Vec<(usize, f64)>> = (0..n)
            .map(|_| (0..m).map(|i| (i, rng.normal())).collect())
            .collect();
        let a = csc(m, cols);
        let b: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let mut ws = LsqrWorkspace::new();
        let cold = lsqr_with(&a, &b, &LsqrOptions::default(), None, &mut ws);
        // Warm start from a perturbation of the cold solution.
        let x0: Vec<f64> = ws.x().iter().map(|&v| v + 0.01).collect();
        let warm = lsqr_with(&a, &b, &LsqrOptions::default(), Some(&x0), &mut ws);
        assert!(
            (warm.residual_norm - cold.residual_norm).abs() < 1e-8 * (1.0 + cold.residual_norm),
            "warm {} vs cold {}",
            warm.residual_norm,
            cold.residual_norm
        );
    }

    #[test]
    fn warm_start_at_exact_solution_converges_immediately() {
        // A x = b solvable: warm-starting at the solution gives a zero
        // deflated rhs and an instant exit.
        let a = csc(2, vec![vec![(0, 2.0), (1, 1.0)], vec![(0, 1.0), (1, 3.0)]]);
        let mut ws = LsqrWorkspace::new();
        let s = lsqr_with(&a, &[5.0, 10.0], &LsqrOptions::default(), Some(&[1.0, 3.0]), &mut ws);
        assert_eq!(s.iterations, 0);
        assert!(s.residual_norm < 1e-12);
        assert!((ws.x()[0] - 1.0).abs() < 1e-12 && (ws.x()[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn workspace_reuse_across_shapes() {
        // Shrinking and growing dims must not leak state between solves.
        let mut ws = LsqrWorkspace::new();
        let a1 = csc(3, vec![vec![(0, 1.0), (1, 1.0), (2, 1.0)]]);
        let s1 = lsqr_with(&a1, &[1.0, 2.0, 3.0], &LsqrOptions::default(), None, &mut ws);
        assert!((ws.x()[0] - 2.0).abs() < 1e-10);
        assert!((s1.residual_norm - 2.0_f64.sqrt()).abs() < 1e-10);

        let a2 = csc(2, vec![vec![(0, 2.0), (1, 1.0)], vec![(0, 1.0), (1, 3.0)]]);
        let s2 = lsqr_with(&a2, &[5.0, 10.0], &LsqrOptions::default(), None, &mut ws);
        assert!(s2.residual_norm < 1e-9);
        assert_eq!(ws.x().len(), 2);
        assert!((ws.x()[0] - 1.0).abs() < 1e-8 && (ws.x()[1] - 3.0).abs() < 1e-8);
    }

    #[test]
    fn random_tall_system_agrees_with_normal_equations() {
        use crate::util::Rng;
        let mut rng = Rng::new(42);
        let (m, n) = (30, 8);
        let cols: Vec<Vec<(usize, f64)>> = (0..n)
            .map(|_| (0..m).map(|i| (i, rng.normal())).collect())
            .collect();
        let a = csc(m, cols);
        let b: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let r = lsqr(&a, &b, &LsqrOptions::default());
        // Optimality condition: A^T (A x - b) = 0.
        let ax = a.matvec(&r.x);
        let res: Vec<f64> = ax.iter().zip(&b).map(|(axi, bi)| axi - bi).collect();
        let grad = a.t_matvec(&res);
        assert!(norm2(&grad) < 1e-6, "gradient norm {}", norm2(&grad));
    }
}
