//! LSQR (Paige & Saunders 1982) — the optimal decoder's solver.
//!
//! Solves min_x ||A x - b||_2 using only matvec / t_matvec, so it runs
//! directly on the sparse non-straggler matrix A without forming A^T A.
//! This matters for the paper's Algorithm 2: A is k x r, sparse (s
//! entries per column) and often rank-deficient (FRC has duplicate
//! columns); LSQR converges to the minimum-norm least-squares solution.

use super::sparse::CscMatrix;

/// Convergence report for an LSQR run.
#[derive(Clone, Debug)]
pub struct LsqrResult {
    pub x: Vec<f64>,
    /// ||A x - b||_2 at the returned iterate.
    pub residual_norm: f64,
    pub iterations: usize,
    pub converged: bool,
}

/// Options for `lsqr`.
#[derive(Clone, Debug)]
pub struct LsqrOptions {
    pub atol: f64,
    pub btol: f64,
    pub max_iter: usize,
}

impl Default for LsqrOptions {
    fn default() -> Self {
        LsqrOptions { atol: 1e-12, btol: 1e-12, max_iter: 0 }
    }
}

/// min_x ||A x - b||. `max_iter = 0` defaults to 4 * max(rows, cols).
pub fn lsqr(a: &CscMatrix, b: &[f64], opts: &LsqrOptions) -> LsqrResult {
    let (m, n) = (a.rows, a.cols);
    assert_eq!(b.len(), m);
    let max_iter = if opts.max_iter == 0 { 4 * m.max(n) } else { opts.max_iter };

    let norm = |v: &[f64]| v.iter().map(|x| x * x).sum::<f64>().sqrt();

    // Golub-Kahan bidiagonalization state.
    let mut u = b.to_vec();
    let mut beta = norm(&u);
    let mut x = vec![0.0; n];
    if beta == 0.0 {
        return LsqrResult { x, residual_norm: 0.0, iterations: 0, converged: true };
    }
    for ui in u.iter_mut() {
        *ui /= beta;
    }
    let mut v = a.t_matvec(&u);
    let mut alpha = norm(&v);
    if alpha == 0.0 {
        // b orthogonal to range(A): x = 0 is optimal.
        return LsqrResult { x, residual_norm: beta, iterations: 0, converged: true };
    }
    for vi in v.iter_mut() {
        *vi /= alpha;
    }

    let mut w = v.clone();
    let mut phi_bar = beta;
    let mut rho_bar = alpha;
    let b_norm = beta;
    let mut a_norm_sq = 0.0; // running estimate of ||A||_F^2 over the Krylov basis

    let mut iterations = 0;
    let mut converged = false;

    // Scratch buffers reused across iterations (perf: allocation-free
    // inner loop — see EXPERIMENTS.md §Perf).
    let mut av = vec![0.0; m];
    let mut atu = vec![0.0; n];

    for it in 1..=max_iter {
        iterations = it;

        // u = A v - alpha u; beta = ||u||
        a.matvec_into(&v, &mut av);
        for i in 0..m {
            u[i] = av[i] - alpha * u[i];
        }
        beta = norm(&u);
        if beta > 0.0 {
            for ui in u.iter_mut() {
                *ui /= beta;
            }
        }

        // v = A^T u - beta v; alpha = ||v||
        a.t_matvec_into(&u, &mut atu);
        for j in 0..n {
            v[j] = atu[j] - beta * v[j];
        }
        alpha = norm(&v);
        if alpha > 0.0 {
            for vi in v.iter_mut() {
                *vi /= alpha;
            }
        }

        a_norm_sq += alpha * alpha + beta * beta;

        // Givens rotation to eliminate beta from the bidiagonal system.
        let rho = (rho_bar * rho_bar + beta * beta).sqrt();
        let c = rho_bar / rho;
        let s = beta / rho;
        let theta = s * alpha;
        rho_bar = -c * alpha;
        let phi = c * phi_bar;
        phi_bar *= s;

        // Update x and the search direction w.
        let t1 = phi / rho;
        let t2 = -theta / rho;
        for j in 0..n {
            x[j] += t1 * w[j];
            w[j] = v[j] + t2 * w[j];
        }

        // Stopping rules (Paige-Saunders criteria 1 & 2).
        let res = phi_bar; // ||A x - b|| for the current iterate
        let a_norm = a_norm_sq.sqrt();
        // ||A^T r|| estimate:
        let atr = phi_bar * alpha * c.abs();
        if res <= opts.btol * b_norm + opts.atol * a_norm * norm(&x) {
            converged = true;
            break;
        }
        if a_norm > 0.0 && res > 0.0 && atr / (a_norm * res) <= opts.atol {
            converged = true;
            break;
        }
        if alpha == 0.0 {
            converged = true;
            break;
        }
    }

    // Recompute the true residual (phi_bar is an estimate).
    let r: Vec<f64> = {
        let ax = a.matvec(&x);
        b.iter().zip(ax).map(|(bi, axi)| bi - axi).collect()
    };
    LsqrResult { x, residual_norm: norm(&r), iterations, converged }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense::norm2;

    fn csc(rows: usize, cols: Vec<Vec<(usize, f64)>>) -> CscMatrix {
        CscMatrix::from_columns(rows, cols)
    }

    #[test]
    fn solves_square_system_exactly() {
        // A = [[2, 1], [1, 3]], b = [5, 10] -> x = [1, 3]
        let a = csc(2, vec![vec![(0, 2.0), (1, 1.0)], vec![(0, 1.0), (1, 3.0)]]);
        let r = lsqr(&a, &[5.0, 10.0], &LsqrOptions::default());
        assert!(r.residual_norm < 1e-9, "residual {}", r.residual_norm);
        assert!((r.x[0] - 1.0).abs() < 1e-8 && (r.x[1] - 3.0).abs() < 1e-8);
    }

    #[test]
    fn overdetermined_least_squares() {
        // A = [[1],[1],[1]], b = [1, 2, 3] -> x = 2, residual^2 = 2
        let a = csc(3, vec![vec![(0, 1.0), (1, 1.0), (2, 1.0)]]);
        let r = lsqr(&a, &[1.0, 2.0, 3.0], &LsqrOptions::default());
        assert!((r.x[0] - 2.0).abs() < 1e-10);
        assert!((r.residual_norm - 2.0_f64.sqrt()).abs() < 1e-10);
    }

    #[test]
    fn rank_deficient_duplicate_columns() {
        // Two identical columns (the FRC case): minimum-norm solution
        // splits the weight, residual is still the projection error.
        let a = csc(2, vec![vec![(0, 1.0)], vec![(0, 1.0)]]);
        let r = lsqr(&a, &[1.0, 1.0], &LsqrOptions::default());
        // err(A) = ||proj_residual||^2 = 1 (second coordinate unreachable)
        assert!((r.residual_norm - 1.0).abs() < 1e-10, "residual {}", r.residual_norm);
        assert!((r.x[0] + r.x[1] - 1.0).abs() < 1e-8);
    }

    #[test]
    fn zero_rhs() {
        let a = csc(2, vec![vec![(0, 1.0)], vec![(1, 1.0)]]);
        let r = lsqr(&a, &[0.0, 0.0], &LsqrOptions::default());
        assert_eq!(r.x, vec![0.0, 0.0]);
        assert_eq!(r.residual_norm, 0.0);
    }

    #[test]
    fn b_orthogonal_to_range() {
        // A's range is span(e0); b = e1.
        let a = csc(2, vec![vec![(0, 1.0)]]);
        let r = lsqr(&a, &[0.0, 1.0], &LsqrOptions::default());
        assert!(norm2(&r.x) < 1e-12);
        assert!((r.residual_norm - 1.0).abs() < 1e-12);
    }

    #[test]
    fn random_tall_system_agrees_with_normal_equations() {
        use crate::util::Rng;
        let mut rng = Rng::new(42);
        let (m, n) = (30, 8);
        let cols: Vec<Vec<(usize, f64)>> = (0..n)
            .map(|_| (0..m).map(|i| (i, rng.normal())).collect())
            .collect();
        let a = csc(m, cols);
        let b: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let r = lsqr(&a, &b, &LsqrOptions::default());
        // Optimality condition: A^T (A x - b) = 0.
        let ax = a.matvec(&r.x);
        let res: Vec<f64> = ax.iter().zip(&b).map(|(axi, bi)| axi - bi).collect();
        let grad = a.t_matvec(&res);
        assert!(norm2(&grad) < 1e-6, "gradient norm {}", norm2(&grad));
    }
}
