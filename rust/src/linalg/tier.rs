//! Runtime SIMD lane-tier detection for the panel decode kernels.
//!
//! The `simd` cargo feature compiles three intrinsics tiers for the
//! lane-inner loops in [`super::panel`] / [`super::blocked`] —
//! SSE2 (the x86_64 baseline), AVX2, and (behind the additional
//! `avx512` feature) AVX-512F — and this module picks the widest one
//! the running CPU supports via `is_x86_feature_detected!`, once,
//! cached in an atomic. Without the feature, or off x86_64, the tier
//! is [`SimdTier::Portable`] and every kernel keeps its portable loop.
//!
//! Bit-parity is tier-independent by construction: panel lanes are
//! independent IEEE accumulators, so packing 2 (SSE2), 4 (AVX2), or
//! 8 (AVX-512) of them into one register performs the *same* per-lane
//! mul/add sequence as the scalar loop — no FMA contraction, no
//! reassociation. `tests/decode_parity.rs` pins this at every tier the
//! CI matrix can reach.
//!
//! [`cap_simd_tier`] lets benches force a *lower* tier to record
//! per-tier throughput (`panel/*` records in BENCH_decode.json); the
//! cap is clamped to the detected capability, so it can never enable
//! instructions the CPU lacks.

use std::sync::atomic::{AtomicU8, Ordering};

/// The SIMD tier driving the lane-inner loops, widest first wins.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum SimdTier {
    /// No intrinsics: the portable lane loops (also the only tier off
    /// x86_64 or without `--features simd`).
    Portable = 0,
    /// 2 f64 lanes per register (baseline on x86_64).
    Sse2 = 1,
    /// 4 f64 lanes per register (runtime-detected).
    Avx2 = 2,
    /// 8 f64 lanes per register (runtime-detected; needs the `avx512`
    /// cargo feature so the crate still builds on toolchains without
    /// stable AVX-512 intrinsics).
    Avx512 = 3,
}

impl SimdTier {
    /// Stable label for bench records and logs.
    pub fn name(self) -> &'static str {
        match self {
            SimdTier::Portable => "portable",
            SimdTier::Sse2 => "sse2",
            SimdTier::Avx2 => "avx2",
            SimdTier::Avx512 => "avx512",
        }
    }
}

const TIER_UNSET: u8 = u8::MAX;

/// Cached result of [`detect`] (set on first query).
static DETECTED: AtomicU8 = AtomicU8::new(TIER_UNSET);
/// Bench-only cap; `TIER_UNSET` means "no cap".
static CAP: AtomicU8 = AtomicU8::new(TIER_UNSET);

fn from_u8(v: u8) -> SimdTier {
    match v {
        0 => SimdTier::Portable,
        1 => SimdTier::Sse2,
        2 => SimdTier::Avx2,
        _ => SimdTier::Avx512,
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
fn detect() -> SimdTier {
    #[cfg(feature = "avx512")]
    {
        if is_x86_feature_detected!("avx512f") {
            return SimdTier::Avx512;
        }
    }
    if is_x86_feature_detected!("avx2") {
        SimdTier::Avx2
    } else {
        SimdTier::Sse2
    }
}

#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
fn detect() -> SimdTier {
    SimdTier::Portable
}

/// The tier the CPU (and feature set) supports, detected once.
pub fn detected_simd_tier() -> SimdTier {
    let t = DETECTED.load(Ordering::Relaxed);
    if t != TIER_UNSET {
        return from_u8(t);
    }
    let d = detect();
    DETECTED.store(d as u8, Ordering::Relaxed);
    d
}

/// The tier the kernels dispatch on right now: the detected tier,
/// unless a bench capped it lower.
pub fn simd_tier() -> SimdTier {
    let cap = CAP.load(Ordering::Relaxed);
    if cap != TIER_UNSET {
        return from_u8(cap);
    }
    detected_simd_tier()
}

/// Cap the dispatch tier (bench plumbing for per-tier throughput
/// records). Clamped to the detected capability; returns the tier that
/// actually took effect. Lanes are bit-identical across tiers, so a
/// concurrent capped/uncapped mix cannot change any result — only
/// speed. Undo with [`uncap_simd_tier`].
pub fn cap_simd_tier(cap: SimdTier) -> SimdTier {
    let applied = cap.min(detected_simd_tier());
    CAP.store(applied as u8, Ordering::Relaxed);
    applied
}

/// Remove a [`cap_simd_tier`] cap, returning dispatch to the detected
/// tier.
pub fn uncap_simd_tier() {
    CAP.store(TIER_UNSET, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detected_tier_is_consistent_with_build_config() {
        let t = detected_simd_tier();
        #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
        assert_eq!(t, SimdTier::Portable);
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        assert!(t >= SimdTier::Sse2, "x86_64 baseline is SSE2, got {t:?}");
        // Idempotent (cached).
        assert_eq!(detected_simd_tier(), t);
    }

    #[test]
    fn cap_clamps_to_capability_and_uncaps() {
        let detected = detected_simd_tier();
        // Capping above the capability stays at the capability.
        assert_eq!(cap_simd_tier(SimdTier::Avx512), detected.min(SimdTier::Avx512));
        // Capping below always takes effect.
        assert_eq!(cap_simd_tier(SimdTier::Portable), SimdTier::Portable);
        assert_eq!(simd_tier(), SimdTier::Portable);
        uncap_simd_tier();
        assert_eq!(simd_tier(), detected);
    }

    #[test]
    fn tier_names_are_stable() {
        assert_eq!(SimdTier::Portable.name(), "portable");
        assert_eq!(SimdTier::Sse2.name(), "sse2");
        assert_eq!(SimdTier::Avx2.name(), "avx2");
        assert_eq!(SimdTier::Avx512.name(), "avx512");
    }
}
