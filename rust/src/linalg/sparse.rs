//! Compressed-sparse-column matrices — the native representation of
//! assignment matrices G and non-straggler submatrices A.
//!
//! Columns are first-class because the paper's objects are column-wise:
//! column j of G is worker j's task list + combination coefficients, and
//! A is a *column* submatrix of G. CSC makes `select_columns` (straggler
//! removal) and the one-step decode (a column-sum pass) O(nnz).

use super::dense::DenseMatrix;

/// Sparse matrix in CSC layout with explicit f64 values.
#[derive(Clone, Debug, PartialEq)]
pub struct CscMatrix {
    pub rows: usize,
    pub cols: usize,
    /// `col_ptr[j]..col_ptr[j+1]` indexes `row_idx`/`vals` for column j.
    pub col_ptr: Vec<usize>,
    pub row_idx: Vec<usize>,
    pub vals: Vec<f64>,
}

impl CscMatrix {
    /// Build from per-column (row, value) lists. Rows within a column
    /// need not be sorted; they are sorted here for deterministic layout.
    pub fn from_columns(rows: usize, columns: Vec<Vec<(usize, f64)>>) -> Self {
        let cols = columns.len();
        let mut col_ptr = Vec::with_capacity(cols + 1);
        let mut row_idx = Vec::new();
        let mut vals = Vec::new();
        col_ptr.push(0);
        for mut col in columns {
            col.sort_unstable_by_key(|&(r, _)| r);
            for (r, v) in col {
                assert!(r < rows, "row index {r} out of bounds ({rows})");
                row_idx.push(r);
                vals.push(v);
            }
            col_ptr.push(row_idx.len());
        }
        CscMatrix { rows, cols, col_ptr, row_idx, vals }
    }

    /// Build a boolean matrix from per-column support sets (all values 1).
    pub fn from_supports(rows: usize, supports: Vec<Vec<usize>>) -> Self {
        Self::from_columns(
            rows,
            supports
                .into_iter()
                .map(|s| s.into_iter().map(|r| (r, 1.0)).collect())
                .collect(),
        )
    }

    pub fn nnz(&self) -> usize {
        self.row_idx.len()
    }

    /// Entries of column j as (row, value) pairs.
    pub fn col(&self, j: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let range = self.col_ptr[j]..self.col_ptr[j + 1];
        self.row_idx[range.clone()].iter().copied().zip(self.vals[range].iter().copied())
    }

    pub fn col_nnz(&self, j: usize) -> usize {
        self.col_ptr[j + 1] - self.col_ptr[j]
    }

    /// An empty 0×0 matrix — the starting state for workspace buffers
    /// that are filled via [`CscMatrix::select_columns_into`].
    pub fn empty() -> CscMatrix {
        CscMatrix { rows: 0, cols: 0, col_ptr: vec![0], row_idx: Vec::new(), vals: Vec::new() }
    }

    /// The column-submatrix with the given column indices (the paper's A
    /// from G given the non-straggler set). Indices may repeat.
    ///
    /// Allocating reference path; the Monte-Carlo hot loop uses
    /// [`CscMatrix::select_columns_into`] to reuse one buffer across
    /// trials (parity between the two is pinned by tests).
    pub fn select_columns(&self, idx: &[usize]) -> CscMatrix {
        let mut col_ptr = Vec::with_capacity(idx.len() + 1);
        let nnz_est: usize = idx.iter().map(|&j| self.col_nnz(j)).sum();
        let mut row_idx = Vec::with_capacity(nnz_est);
        let mut vals = Vec::with_capacity(nnz_est);
        col_ptr.push(0);
        for &j in idx {
            assert!(j < self.cols, "column {j} out of bounds ({})", self.cols);
            let range = self.col_ptr[j]..self.col_ptr[j + 1];
            row_idx.extend_from_slice(&self.row_idx[range.clone()]);
            vals.extend_from_slice(&self.vals[range]);
            col_ptr.push(row_idx.len());
        }
        CscMatrix { rows: self.rows, cols: idx.len(), col_ptr, row_idx, vals }
    }

    /// [`CscMatrix::select_columns`] into a caller-owned matrix, reusing
    /// its buffers: zero heap traffic once `out`'s capacity has grown to
    /// the largest submatrix seen (the steady state of the trial loop).
    /// The layout and value order are identical to the allocating path.
    pub fn select_columns_into(&self, idx: &[usize], out: &mut CscMatrix) {
        out.rows = self.rows;
        out.cols = idx.len();
        out.col_ptr.clear();
        out.row_idx.clear();
        out.vals.clear();
        out.col_ptr.push(0);
        for &j in idx {
            assert!(j < self.cols, "column {j} out of bounds ({})", self.cols);
            let range = self.col_ptr[j]..self.col_ptr[j + 1];
            out.row_idx.extend_from_slice(&self.row_idx[range.clone()]);
            out.vals.extend_from_slice(&self.vals[range]);
            out.col_ptr.push(out.row_idx.len());
        }
    }

    /// y = A x (x over columns). O(nnz).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// y = A x written into a caller-provided buffer (hot-path variant:
    /// LSQR and the algorithmic decoder call this every iteration, so
    /// per-iteration allocation would dominate at the paper's k=100).
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        y.fill(0.0);
        for j in 0..self.cols {
            let xj = x[j];
            if xj == 0.0 {
                continue;
            }
            for k in self.col_ptr[j]..self.col_ptr[j + 1] {
                y[self.row_idx[k]] += self.vals[k] * xj;
            }
        }
    }

    /// y = A^T x (x over rows). O(nnz).
    pub fn t_matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.cols];
        self.t_matvec_into(x, &mut y);
        y
    }

    /// y = A^T x into a caller-provided buffer (see `matvec_into`).
    pub fn t_matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        for j in 0..self.cols {
            let mut acc = 0.0;
            for k in self.col_ptr[j]..self.col_ptr[j + 1] {
                acc += self.vals[k] * x[self.row_idx[k]];
            }
            y[j] = acc;
        }
    }

    /// Row sums: A 1_cols in one pass (the one-step decode hot path).
    pub fn row_sums(&self) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        for k in 0..self.nnz() {
            y[self.row_idx[k]] += self.vals[k];
        }
        y
    }

    /// [`CscMatrix::row_sums`] into a reused buffer (resized to `rows`,
    /// keeping capacity). Same accumulation order as the allocating path.
    pub fn row_sums_into(&self, y: &mut Vec<f64>) {
        y.clear();
        y.resize(self.rows, 0.0);
        for k in 0..self.nnz() {
            y[self.row_idx[k]] += self.vals[k];
        }
    }

    /// Per-row nonzero counts (left-vertex degrees of the bipartite view).
    pub fn row_degrees(&self) -> Vec<usize> {
        let mut d = vec![0usize; self.rows];
        for &r in &self.row_idx {
            d[r] += 1;
        }
        d
    }

    pub fn to_dense(&self) -> DenseMatrix {
        let mut m = DenseMatrix::zeros(self.rows, self.cols);
        for j in 0..self.cols {
            for k in self.col_ptr[j]..self.col_ptr[j + 1] {
                m[(self.row_idx[k], j)] += self.vals[k];
            }
        }
        m
    }

    /// Support (sorted row indices) of column j — used to hash duplicate
    /// columns in the FRC adversary.
    pub fn col_support(&self, j: usize) -> &[usize] {
        &self.row_idx[self.col_ptr[j]..self.col_ptr[j + 1]]
    }

    /// Remove entries of column j, keeping only rows for which `keep`
    /// is true (the rBGC-style per-column thinning primitive). Later
    /// columns' storage shifts left; O(nnz) worst case, O(col_nnz(j) +
    /// tail) moved.
    pub fn retain_rows_in_col(&mut self, j: usize, keep: &[bool]) {
        assert!(j < self.cols, "column {j} out of bounds ({})", self.cols);
        assert_eq!(keep.len(), self.rows, "keep mask length != rows");
        let start = self.col_ptr[j];
        let end = self.col_ptr[j + 1];
        let mut write = start;
        for read in start..end {
            if keep[self.row_idx[read]] {
                self.row_idx[write] = self.row_idx[read];
                self.vals[write] = self.vals[read];
                write += 1;
            }
        }
        let removed = end - write;
        if removed > 0 {
            self.row_idx.copy_within(end.., write);
            self.vals.copy_within(end.., write);
            let new_len = self.row_idx.len() - removed;
            self.row_idx.truncate(new_len);
            self.vals.truncate(new_len);
            for p in self.col_ptr[j + 1..].iter_mut() {
                *p -= removed;
            }
        }
    }

    /// True when every stored value is 1 (a boolean assignment matrix,
    /// the form all of the paper's code constructions produce).
    pub fn is_boolean(&self) -> bool {
        self.vals.iter().all(|&v| v == 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn example() -> CscMatrix {
        // [[1, 0, 2],
        //  [0, 3, 0],
        //  [4, 0, 5]]
        CscMatrix::from_columns(
            3,
            vec![vec![(0, 1.0), (2, 4.0)], vec![(1, 3.0)], vec![(0, 2.0), (2, 5.0)]],
        )
    }

    #[test]
    fn matvec_matches_dense() {
        let a = example();
        let x = vec![1.0, 2.0, 3.0];
        assert_eq!(a.matvec(&x), a.to_dense().matvec(&x));
    }

    #[test]
    fn t_matvec_matches_dense() {
        let a = example();
        let x = vec![1.0, -1.0, 0.5];
        assert_eq!(a.t_matvec(&x), a.to_dense().t_matvec(&x));
    }

    #[test]
    fn select_columns_subsets() {
        let a = example();
        let s = a.select_columns(&[2, 0]);
        assert_eq!(s.cols, 2);
        assert_eq!(s.to_dense().col(0), vec![2.0, 0.0, 5.0]);
        assert_eq!(s.to_dense().col(1), vec![1.0, 0.0, 4.0]);
    }

    #[test]
    fn select_columns_allows_repeats() {
        let a = example();
        let s = a.select_columns(&[1, 1]);
        assert_eq!(s.cols, 2);
        assert_eq!(s.nnz(), 2);
    }

    #[test]
    fn row_sums_matches_matvec_ones() {
        let a = example();
        assert_eq!(a.row_sums(), a.matvec(&vec![1.0; 3]));
    }

    #[test]
    fn degrees_and_support() {
        let a = example();
        assert_eq!(a.row_degrees(), vec![2, 1, 2]);
        assert_eq!(a.col_support(0), &[0, 2]);
        assert_eq!(a.col_nnz(1), 1);
    }

    #[test]
    fn from_supports_boolean() {
        let a = CscMatrix::from_supports(4, vec![vec![0, 3], vec![1]]);
        assert!(a.is_boolean());
        assert_eq!(a.nnz(), 3);
    }

    #[test]
    fn unsorted_columns_are_sorted() {
        let a = CscMatrix::from_columns(3, vec![vec![(2, 5.0), (0, 1.0)]]);
        assert_eq!(a.col_support(0), &[0, 2]);
    }

    #[test]
    #[should_panic]
    fn out_of_bounds_row_panics() {
        let _ = CscMatrix::from_supports(2, vec![vec![5]]);
    }

    /// The `_into` variant must match the allocating path exactly — for
    /// repeated columns (FRC duplicate workers), the empty index set,
    /// and the full-range identity selection — while reusing buffers.
    #[test]
    fn select_columns_into_matches_allocating_variant() {
        let a = example();
        let mut out = CscMatrix::empty();
        let cases: Vec<Vec<usize>> = vec![
            vec![1, 1],          // repeated column indices
            vec![],              // empty index set
            vec![0, 1, 2],       // full-range identity
            vec![2, 0],          // reorder
            vec![2, 2, 2, 2],    // many repeats, forcing buffer growth
            vec![1],             // shrink back down (buffers must reset)
        ];
        for idx in &cases {
            let reference = a.select_columns(idx);
            a.select_columns_into(idx, &mut out);
            assert_eq!(out, reference, "idx = {idx:?}");
        }
    }

    #[test]
    fn select_columns_into_empty_set_is_kx0() {
        let a = example();
        let mut out = CscMatrix::empty();
        a.select_columns_into(&[], &mut out);
        assert_eq!(out.rows, 3);
        assert_eq!(out.cols, 0);
        assert_eq!(out.nnz(), 0);
        assert_eq!(out.col_ptr, vec![0]);
    }

    #[test]
    fn select_columns_into_full_identity_roundtrips() {
        let a = example();
        let mut out = CscMatrix::empty();
        a.select_columns_into(&[0, 1, 2], &mut out);
        assert_eq!(out, a);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn select_columns_into_oob_panics() {
        let a = example();
        let mut out = CscMatrix::empty();
        a.select_columns_into(&[3], &mut out);
    }

    #[test]
    fn row_sums_into_matches_row_sums() {
        let a = example();
        let mut buf = vec![99.0; 1]; // wrong size on purpose: must resize
        a.row_sums_into(&mut buf);
        assert_eq!(buf, a.row_sums());
    }

    #[test]
    fn retain_rows_in_col_filters_and_shifts() {
        let mut a = example();
        // Drop row 2 from column 0: [[1,0,2],[0,3,0],[0,0,5]].
        a.retain_rows_in_col(0, &[true, true, false]);
        assert_eq!(a.col_support(0), &[0]);
        assert_eq!(a.col_support(1), &[1]);
        assert_eq!(a.col_support(2), &[0, 2]); // later columns intact
        assert_eq!(a.nnz(), 4);
        assert_eq!(a.to_dense().col(2), vec![2.0, 0.0, 5.0]);
    }

    #[test]
    fn retain_rows_in_col_keep_all_is_noop() {
        let mut a = example();
        let before = a.clone();
        a.retain_rows_in_col(1, &[true, true, true]);
        assert_eq!(a, before);
    }

    #[test]
    fn retain_rows_in_col_drop_all_empties_column() {
        let mut a = example();
        a.retain_rows_in_col(2, &[false, false, false]);
        assert_eq!(a.col_nnz(2), 0);
        assert_eq!(a.nnz(), 3);
        // Structure still valid: col_ptr monotone, ends at nnz.
        assert_eq!(*a.col_ptr.last().unwrap(), a.nnz());
    }
}
