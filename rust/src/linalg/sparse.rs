//! Compressed-sparse-column matrices — the native representation of
//! assignment matrices G and non-straggler submatrices A.
//!
//! Columns are first-class because the paper's objects are column-wise:
//! column j of G is worker j's task list + combination coefficients, and
//! A is a *column* submatrix of G. CSC makes `select_columns` (straggler
//! removal) and the one-step decode (a column-sum pass) O(nnz).

use super::dense::DenseMatrix;

/// Sparse matrix in CSC layout with explicit f64 values.
#[derive(Clone, Debug, PartialEq)]
pub struct CscMatrix {
    pub rows: usize,
    pub cols: usize,
    /// col_ptr[j]..col_ptr[j+1] indexes row_idx/vals for column j.
    pub col_ptr: Vec<usize>,
    pub row_idx: Vec<usize>,
    pub vals: Vec<f64>,
}

impl CscMatrix {
    /// Build from per-column (row, value) lists. Rows within a column
    /// need not be sorted; they are sorted here for deterministic layout.
    pub fn from_columns(rows: usize, columns: Vec<Vec<(usize, f64)>>) -> Self {
        let cols = columns.len();
        let mut col_ptr = Vec::with_capacity(cols + 1);
        let mut row_idx = Vec::new();
        let mut vals = Vec::new();
        col_ptr.push(0);
        for mut col in columns {
            col.sort_unstable_by_key(|&(r, _)| r);
            for (r, v) in col {
                assert!(r < rows, "row index {r} out of bounds ({rows})");
                row_idx.push(r);
                vals.push(v);
            }
            col_ptr.push(row_idx.len());
        }
        CscMatrix { rows, cols, col_ptr, row_idx, vals }
    }

    /// Build a boolean matrix from per-column support sets (all values 1).
    pub fn from_supports(rows: usize, supports: Vec<Vec<usize>>) -> Self {
        Self::from_columns(
            rows,
            supports
                .into_iter()
                .map(|s| s.into_iter().map(|r| (r, 1.0)).collect())
                .collect(),
        )
    }

    pub fn nnz(&self) -> usize {
        self.row_idx.len()
    }

    /// Entries of column j as (row, value) pairs.
    pub fn col(&self, j: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let range = self.col_ptr[j]..self.col_ptr[j + 1];
        self.row_idx[range.clone()].iter().copied().zip(self.vals[range].iter().copied())
    }

    pub fn col_nnz(&self, j: usize) -> usize {
        self.col_ptr[j + 1] - self.col_ptr[j]
    }

    /// The column-submatrix with the given column indices (the paper's A
    /// from G given the non-straggler set). Indices may repeat.
    pub fn select_columns(&self, idx: &[usize]) -> CscMatrix {
        let mut col_ptr = Vec::with_capacity(idx.len() + 1);
        let nnz_est: usize = idx.iter().map(|&j| self.col_nnz(j)).sum();
        let mut row_idx = Vec::with_capacity(nnz_est);
        let mut vals = Vec::with_capacity(nnz_est);
        col_ptr.push(0);
        for &j in idx {
            assert!(j < self.cols, "column {j} out of bounds ({})", self.cols);
            let range = self.col_ptr[j]..self.col_ptr[j + 1];
            row_idx.extend_from_slice(&self.row_idx[range.clone()]);
            vals.extend_from_slice(&self.vals[range]);
            col_ptr.push(row_idx.len());
        }
        CscMatrix { rows: self.rows, cols: idx.len(), col_ptr, row_idx, vals }
    }

    /// y = A x (x over columns). O(nnz).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// y = A x written into a caller-provided buffer (hot-path variant:
    /// LSQR and the algorithmic decoder call this every iteration, so
    /// per-iteration allocation would dominate at the paper's k=100).
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        y.fill(0.0);
        for j in 0..self.cols {
            let xj = x[j];
            if xj == 0.0 {
                continue;
            }
            for k in self.col_ptr[j]..self.col_ptr[j + 1] {
                y[self.row_idx[k]] += self.vals[k] * xj;
            }
        }
    }

    /// y = A^T x (x over rows). O(nnz).
    pub fn t_matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.cols];
        self.t_matvec_into(x, &mut y);
        y
    }

    /// y = A^T x into a caller-provided buffer (see `matvec_into`).
    pub fn t_matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        for j in 0..self.cols {
            let mut acc = 0.0;
            for k in self.col_ptr[j]..self.col_ptr[j + 1] {
                acc += self.vals[k] * x[self.row_idx[k]];
            }
            y[j] = acc;
        }
    }

    /// Row sums: A 1_cols in one pass (the one-step decode hot path).
    pub fn row_sums(&self) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        for k in 0..self.nnz() {
            y[self.row_idx[k]] += self.vals[k];
        }
        y
    }

    /// Per-row nonzero counts (left-vertex degrees of the bipartite view).
    pub fn row_degrees(&self) -> Vec<usize> {
        let mut d = vec![0usize; self.rows];
        for &r in &self.row_idx {
            d[r] += 1;
        }
        d
    }

    pub fn to_dense(&self) -> DenseMatrix {
        let mut m = DenseMatrix::zeros(self.rows, self.cols);
        for j in 0..self.cols {
            for k in self.col_ptr[j]..self.col_ptr[j + 1] {
                m[(self.row_idx[k], j)] += self.vals[k];
            }
        }
        m
    }

    /// Support (sorted row indices) of column j — used to hash duplicate
    /// columns in the FRC adversary.
    pub fn col_support(&self, j: usize) -> &[usize] {
        &self.row_idx[self.col_ptr[j]..self.col_ptr[j + 1]]
    }

    /// Remove entries of column j, keeping only rows in `keep` (used by
    /// rBGC regularization).
    pub fn is_boolean(&self) -> bool {
        self.vals.iter().all(|&v| v == 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn example() -> CscMatrix {
        // [[1, 0, 2],
        //  [0, 3, 0],
        //  [4, 0, 5]]
        CscMatrix::from_columns(
            3,
            vec![vec![(0, 1.0), (2, 4.0)], vec![(1, 3.0)], vec![(0, 2.0), (2, 5.0)]],
        )
    }

    #[test]
    fn matvec_matches_dense() {
        let a = example();
        let x = vec![1.0, 2.0, 3.0];
        assert_eq!(a.matvec(&x), a.to_dense().matvec(&x));
    }

    #[test]
    fn t_matvec_matches_dense() {
        let a = example();
        let x = vec![1.0, -1.0, 0.5];
        assert_eq!(a.t_matvec(&x), a.to_dense().t_matvec(&x));
    }

    #[test]
    fn select_columns_subsets() {
        let a = example();
        let s = a.select_columns(&[2, 0]);
        assert_eq!(s.cols, 2);
        assert_eq!(s.to_dense().col(0), vec![2.0, 0.0, 5.0]);
        assert_eq!(s.to_dense().col(1), vec![1.0, 0.0, 4.0]);
    }

    #[test]
    fn select_columns_allows_repeats() {
        let a = example();
        let s = a.select_columns(&[1, 1]);
        assert_eq!(s.cols, 2);
        assert_eq!(s.nnz(), 2);
    }

    #[test]
    fn row_sums_matches_matvec_ones() {
        let a = example();
        assert_eq!(a.row_sums(), a.matvec(&vec![1.0; 3]));
    }

    #[test]
    fn degrees_and_support() {
        let a = example();
        assert_eq!(a.row_degrees(), vec![2, 1, 2]);
        assert_eq!(a.col_support(0), &[0, 2]);
        assert_eq!(a.col_nnz(1), 1);
    }

    #[test]
    fn from_supports_boolean() {
        let a = CscMatrix::from_supports(4, vec![vec![0, 3], vec![1]]);
        assert!(a.is_boolean());
        assert_eq!(a.nnz(), 3);
    }

    #[test]
    fn unsorted_columns_are_sorted() {
        let a = CscMatrix::from_columns(3, vec![vec![(2, 5.0), (0, 1.0)]]);
        assert_eq!(a.col_support(0), &[0, 2]);
    }

    #[test]
    #[should_panic]
    fn out_of_bounds_row_panics() {
        let _ = CscMatrix::from_supports(2, vec![vec![5]]);
    }
}
