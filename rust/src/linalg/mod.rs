//! Numerical substrate: dense/sparse matrices, least-squares solvers,
//! and spectral estimation. Everything the decoders and the adversarial
//! analysis need, built from scratch (no external linalg crates in the
//! offline vendor set).
//!
//! # CSC vs CSR — who owns which pass
//!
//! [`CscMatrix`] is the **native** layout: the paper's objects are
//! column-wise (column j = worker j's task list), so straggler removal
//! (`select_columns*`) and the fused one-step accumulation walk columns
//! and are O(nnz) in CSC. [`CsrMatrix`] is the **row-major mirror** for
//! the decode inner loops that reduce over rows — row coverage, row
//! sums, the streamed one-step error — which in CSC scatter through
//! memory. The mirror is built once per G ([`CscMatrix::to_csr`] /
//! [`CscMatrix::to_csr_into`]) and cached in `decode::DecodeWorkspace`;
//! the conversion is a stable counting-sort transpose, so every CSR
//! kernel accumulates in the same order as its CSC counterpart and the
//! two layouts produce bit-identical results (`tests/linalg_parity.rs`).
//!
//! # Blocking convention
//!
//! [`blocked`] holds the SIMD-friendly kernels (manual 4-lane blocking,
//! scalar tail) used by the LSQR inner loop and the CSR row reductions:
//! four independent accumulators over indices `4c + lane`, combined as
//! `(a0 + a1) + (a2 + a3)`, tail added last. Elementwise kernels are
//! bit-identical to their scalar loops; reduction kernels reassociate
//! (exact on integer-valued data — every boolean assignment matrix —
//! and within rounding otherwise). Both `lsqr` and `lsqr_with` use the
//! same blocked kernels, so their mutual bit-parity is preserved.
//!
//! # Panel layer
//!
//! [`panel`] lifts the hot decode kernels to **multi-RHS panels**: W
//! concurrent trials against one shared G, so each pass over G's
//! nonzeros serves W lanes instead of one. Selected-submatrix matvecs
//! avoid materializing A entirely, and the lockstep panel LSQR runs W
//! solves per sweep — all while keeping every lane bit-identical to the
//! scalar path (see the module docs for the exactness argument). The
//! optional `simd` cargo feature swaps the lane-inner loops for x86_64
//! intrinsics, runtime-dispatched across lane tiers — SSE2 baseline,
//! AVX2 when detected, AVX-512F behind the extra `avx512` feature (see
//! [`tier`]). Every tier performs the same per-lane IEEE operations, so
//! all of them — and the portable default — are bit-identical.

pub mod blocked;
pub mod cholesky;
pub mod csr;
pub mod dense;
pub mod lsqr;
pub mod panel;
pub mod power_iter;
pub mod sparse;
pub mod tier;

pub use csr::CsrMatrix;
pub use dense::{axpy, dot, norm2, norm2_sq, scale, DenseMatrix};
pub use lsqr::{lsqr, lsqr_with, LsqrOptions, LsqrResult, LsqrSummary, LsqrWorkspace};
pub use panel::{
    err1_panel_counts, err1_panel_cov, lsqr_selected_panel, matvec_selected_into, nnz_selected,
    t_matvec_selected_into, PanelLsqr,
};
pub use tier::{cap_simd_tier, detected_simd_tier, simd_tier, uncap_simd_tier, SimdTier};
pub use power_iter::{regular_graph_lambda, spectral_norm};
pub use sparse::CscMatrix;
