//! Numerical substrate: dense/sparse matrices, least-squares solvers,
//! and spectral estimation. Everything the decoders and the adversarial
//! analysis need, built from scratch (no external linalg crates in the
//! offline vendor set).

pub mod cholesky;
pub mod dense;
pub mod lsqr;
pub mod power_iter;
pub mod sparse;

pub use dense::{axpy, dot, norm2, norm2_sq, scale, DenseMatrix};
pub use lsqr::{lsqr, lsqr_with, LsqrOptions, LsqrResult, LsqrSummary, LsqrWorkspace};
pub use power_iter::{regular_graph_lambda, spectral_norm};
pub use sparse::CscMatrix;
