//! Dense Cholesky solve — the cross-validation decoder.
//!
//! For small k the optimal decode can be done by normal equations
//! (A^T A + eps I) x = A^T b with a dense Cholesky factorization. Tests
//! use this to validate LSQR; the figure harness uses LSQR.

use super::dense::DenseMatrix;

/// Cholesky factor L (lower triangular) of an SPD matrix, or None if the
/// matrix is not positive definite within tolerance.
pub fn cholesky(a: &DenseMatrix) -> Option<DenseMatrix> {
    assert_eq!(a.rows, a.cols, "cholesky needs square input");
    let n = a.rows;
    let mut l = DenseMatrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[(i, j)];
            for k in 0..j {
                sum -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l[(i, j)] = sum.sqrt();
            } else {
                l[(i, j)] = sum / l[(j, j)];
            }
        }
    }
    Some(l)
}

/// Solve L y = b (forward substitution).
pub fn forward_sub(l: &DenseMatrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut sum = b[i];
        for k in 0..i {
            sum -= l[(i, k)] * y[k];
        }
        y[i] = sum / l[(i, i)];
    }
    y
}

/// Solve L^T x = y (backward substitution).
pub fn backward_sub(l: &DenseMatrix, y: &[f64]) -> Vec<f64> {
    let n = l.rows;
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut sum = y[i];
        for k in i + 1..n {
            sum -= l[(k, i)] * x[k];
        }
        x[i] = sum / l[(i, i)];
    }
    x
}

/// Solve the regularized normal equations (A^T A + ridge I) x = A^T b.
///
/// `ridge > 0` guarantees positive-definiteness even for rank-deficient A
/// (e.g. FRC's duplicate columns); 1e-10 perturbs err(A) negligibly at
/// the k=100 scales of the paper's figures.
pub fn solve_normal_equations(a: &DenseMatrix, b: &[f64], ridge: f64) -> Option<Vec<f64>> {
    let mut gram = a.gram();
    for i in 0..gram.rows {
        gram[(i, i)] += ridge;
    }
    let l = cholesky(&gram)?;
    let atb = a.t_matvec(b);
    let y = forward_sub(&l, &atb);
    Some(backward_sub(&l, &y))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense::norm2;

    #[test]
    fn factorizes_spd() {
        let a = DenseMatrix::from_rows(&[vec![4.0, 2.0], vec![2.0, 3.0]]);
        let l = cholesky(&a).unwrap();
        let llt = l.matmul(&l.transpose());
        for i in 0..2 {
            for j in 0..2 {
                assert!((llt[(i, j)] - a[(i, j)]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn rejects_indefinite() {
        let a = DenseMatrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]);
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn solves_least_squares_via_normal_equations() {
        let a = DenseMatrix::from_rows(&[vec![1.0, 0.0], vec![1.0, 1.0], vec![1.0, 2.0]]);
        let b = [1.0, 2.0, 4.0];
        let x = solve_normal_equations(&a, &b, 1e-12).unwrap();
        // Known LS solution for this system: intercept 5/6, slope 3/2.
        assert!((x[0] - 5.0 / 6.0).abs() < 1e-6, "{x:?}");
        assert!((x[1] - 1.5).abs() < 1e-6, "{x:?}");
    }

    #[test]
    fn ridge_handles_rank_deficiency() {
        // Duplicate columns: unregularized normal equations are singular.
        let a = DenseMatrix::from_rows(&[vec![1.0, 1.0], vec![0.0, 0.0]]);
        let x = solve_normal_equations(&a, &[1.0, 1.0], 1e-10).unwrap();
        let ax = a.matvec(&x);
        let res = [(ax[0] - 1.0), (ax[1] - 1.0)];
        assert!((norm2(&res) - 1.0).abs() < 1e-5);
    }

    #[test]
    fn triangular_solves_roundtrip() {
        let l = DenseMatrix::from_rows(&[vec![2.0, 0.0], vec![1.0, 3.0]]);
        let b = [4.0, 10.0];
        let y = forward_sub(&l, &b);
        assert!((y[0] - 2.0).abs() < 1e-14 && (y[1] - 8.0 / 3.0).abs() < 1e-14);
        let x = backward_sub(&l, &y);
        // Check L L^T x = b
        let llt = l.matmul(&l.transpose());
        let back = llt.matvec(&x);
        assert!((back[0] - b[0]).abs() < 1e-12 && (back[1] - b[1]).abs() < 1e-12);
    }
}
