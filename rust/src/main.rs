//! `repro` — CLI for the gradcode reproduction.
//!
//! Subcommands (arg parsing is hand-rolled; clap is not in the offline
//! vendor set):
//!
//!   repro figures --fig 2|3|4|5 [--trials N] [--k K] [--seed S]
//!       Regenerate a paper figure's series as CSV on stdout.
//!   repro tables --table thm5|thm6|thm8|thm10|thm11|thm21|thm24
//!       Regenerate a theorem-vs-measured table as CSV.
//!   repro train [--scheme frc|bgc|rbgc|regular|cyclic] [--model linear|mlp]
//!               [--decoder onestep|optimal] [--k K] [--s S] [--steps N]
//!               [--delta D] [--backend pjrt|native] [--engines E]
//!       Run the end-to-end coded training loop; per-round CSV on stdout.
//!   repro adversary [--k K] [--s S] [--r R]
//!       Compare straggler-selection strategies on every code.
//!   repro demo
//!       30-second tour: one figure point, one attack, one training run.

use anyhow::{anyhow, bail, Context, Result};

use gradcode::adversary::{
    asp_objective, frc_worst_stragglers, greedy_stragglers, local_search_stragglers,
};
use gradcode::codes::Scheme;
use gradcode::coordinator::{DecoderKind, ModelKind};
use gradcode::decode::OptimalDecoder;
use gradcode::runtime::{Backend, EnginePool, LinearDims, Manifest, MlpDims};
use gradcode::sim::{figures, tables, FigPoint, FigureConfig, MonteCarlo, TableRow};
use gradcode::stragglers::{DeadlinePolicy, LatencyModel};
use gradcode::training::{train, TrainConfig};
use gradcode::util::Rng;

/// Tiny argv parser: --key value pairs after a subcommand.
struct Args {
    sub: String,
    kv: Vec<(String, String)>,
}

impl Args {
    fn parse() -> Result<Args> {
        let mut it = std::env::args().skip(1);
        let sub = it.next().unwrap_or_else(|| "help".to_string());
        let mut kv = Vec::new();
        while let Some(key) = it.next() {
            let key = key
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got {key:?}"))?
                .to_string();
            let val = it.next().ok_or_else(|| anyhow!("--{key} needs a value"))?;
            kv.push((key, val));
        }
        Ok(Args { sub, kv })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.kv.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    fn usize(&self, key: &str, default: usize) -> Result<usize> {
        self.get(key)
            .map(|v| v.parse::<usize>().with_context(|| format!("--{key} {v:?}")))
            .unwrap_or(Ok(default))
    }

    fn f64(&self, key: &str, default: f64) -> Result<f64> {
        self.get(key)
            .map(|v| v.parse::<f64>().with_context(|| format!("--{key} {v:?}")))
            .unwrap_or(Ok(default))
    }

    fn u64(&self, key: &str, default: u64) -> Result<u64> {
        self.get(key)
            .map(|v| v.parse::<u64>().with_context(|| format!("--{key} {v:?}")))
            .unwrap_or(Ok(default))
    }
}

fn main() -> Result<()> {
    let args = Args::parse()?;
    match args.sub.as_str() {
        "figures" => cmd_figures(&args),
        "tables" => cmd_tables(&args),
        "train" => cmd_train(&args),
        "adversary" => cmd_adversary(&args),
        "ablation" => cmd_ablation(&args),
        "inspect" => cmd_inspect(&args),
        "demo" => cmd_demo(),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}; try `repro help`"),
    }
}

const HELP: &str = "\
repro — Approximate Gradient Coding via Sparse Random Graphs (2017)

USAGE:
  repro figures --fig 2|3|4|5 [--trials N] [--k K] [--seed S] [--tmax T]
  repro tables  --table thm5|thm6|thm8|thm10|thm11|thm21|thm24 [--trials N]
  repro train   [--scheme S] [--model linear|mlp] [--decoder onestep|optimal]
                [--k K] [--s S] [--steps N] [--delta D] [--lr LR]
                [--backend pjrt|native] [--engines E] [--seed S]
  repro adversary [--k K] [--s S] [--r R] [--seed S]
  repro ablation  --study rho|rbgc|lsqr|normalization [--trials N]
  repro inspect   [--artifact NAME]     # HLO stats of an AOT artifact
  repro demo
";

// -------------------------------------------------------------- figures

fn cmd_figures(args: &Args) -> Result<()> {
    let fig = args.usize("fig", 2)?;
    let trials = args.usize("trials", 5000)?;
    let seed = args.u64("seed", 2017)?;
    let k = args.usize("k", 100)?;
    let tmax = args.usize("tmax", 15)?;

    let mut cfg = FigureConfig::paper(trials, seed);
    cfg.k = k;
    let pts: Vec<FigPoint> = match fig {
        2 => figures::figure2(&cfg),
        3 => figures::figure3(&cfg),
        4 => figures::figure4(&cfg),
        5 => figures::figure5(&cfg, tmax),
        other => bail!("unknown figure {other} (paper has figures 2-5)"),
    };
    println!("{}", FigPoint::csv_header());
    for p in pts {
        println!("{}", p.to_csv());
    }
    Ok(())
}

// --------------------------------------------------------------- tables

fn cmd_tables(args: &Args) -> Result<()> {
    let table = args.get("table").unwrap_or("thm5");
    let trials = args.usize("trials", 2000)?;
    let seed = args.u64("seed", 2017)?;
    let k = args.usize("k", 100)?;
    let s = args.usize("s", 10)?;
    let mc = MonteCarlo::new(trials, seed);
    let deltas = [0.1, 0.25, 0.5, 0.75];

    let rows: Vec<TableRow> = match table {
        "thm5" => tables::thm5_table(k, s, &deltas, &mc),
        "thm6" => tables::thm6_table(k, s, &deltas, &mc),
        "thm8" => tables::thm8_table(k, &[0, 1, 2], &[0.1, 0.25, 0.5], &mc),
        "thm10" => tables::thm10_table(k, s, &[k / 4, k / 2, 3 * k / 4], &mc),
        "thm11" => tables::thm11_table(seed),
        "thm21" => tables::thm21_table(
            Scheme::Bgc,
            &[50, 100, 200, 400],
            |k| ((k as f64).ln().ceil() as usize).max(2),
            0.25,
            &mc,
        ),
        "thm24" => tables::thm21_table(
            Scheme::Rbgc,
            &[50, 100, 200, 400],
            |k| ((k as f64).ln().ceil() as usize).max(2),
            0.25,
            &mc,
        ),
        other => bail!("unknown table {other:?}"),
    };
    println!("{}", TableRow::csv_header());
    for r in rows {
        println!("{}", r.to_csv());
    }
    Ok(())
}

// ---------------------------------------------------------------- train

/// Build the requested backend. PJRT needs `make artifacts` first.
fn build_backend(args: &Args) -> Result<(Option<EnginePool>, Backend)> {
    let which = args.get("backend").unwrap_or("pjrt");
    match which {
        "pjrt" => {
            let manifest = Manifest::load(Manifest::default_dir())?;
            let engines = args.usize("engines", 2)?;
            let pool = EnginePool::start(manifest, engines)?;
            let backend = Backend::Pjrt(pool.handle());
            Ok((Some(pool), backend))
        }
        "native" => Ok((
            None,
            // Native dims mirror the aot.py defaults.
            Backend::Native {
                linear: LinearDims { m: 32, d: 64 },
                mlp: MlpDims { m: 32, d_in: 32, d_hidden: 64, d_out: 16, flat_dim: 3152 },
                s_max: 10,
            },
        )),
        other => bail!("unknown backend {other:?} (pjrt|native)"),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let scheme = Scheme::parse(args.get("scheme").unwrap_or("frc"))
        .ok_or_else(|| anyhow!("bad --scheme"))?;
    let model = match args.get("model").unwrap_or("linear") {
        "linear" => ModelKind::Linear,
        "mlp" => ModelKind::Mlp,
        other => bail!("unknown model {other:?}"),
    };
    let k = args.usize("k", 100)?;
    let s = args.usize("s", 10)?;
    let steps = args.usize("steps", 200)?;
    let delta = args.f64("delta", 0.2)?;
    let lr = args.f64("lr", 0.5)?;

    let (_pool, backend) = build_backend(args)?;
    let mut cfg = TrainConfig::new(scheme, k, s, model);
    cfg.steps = steps;
    cfg.lr = lr;
    cfg.coordinator.seed = args.u64("seed", 0)?;
    cfg.coordinator.decoder = DecoderKind::parse(args.get("decoder").unwrap_or("onestep"))
        .ok_or_else(|| anyhow!("bad --decoder"))?;
    cfg.coordinator.latency = LatencyModel::Pareto { scale: 0.02, shape: 1.5 };
    let r = (((1.0 - delta) * k as f64).round() as usize).clamp(1, k);
    cfg.coordinator.deadline = DeadlinePolicy::FastestR(r);

    eprintln!(
        "training {} model, scheme={} k={k} s={s} r={r} decoder={} backend={}",
        match model {
            ModelKind::Linear => "linear",
            ModelKind::Mlp => "mlp",
        },
        scheme.name(),
        cfg.coordinator.decoder.name(),
        backend.name()
    );
    let out = train(&backend, &cfg)?;
    print!("{}", out.history.to_csv());
    eprintln!(
        "final loss {:.6e}, mean decode err {:.3e}, total gather {:.2}s",
        out.history.final_loss(),
        out.history.mean_decode_err(),
        out.history.total_gather_time()
    );
    Ok(())
}

// ------------------------------------------------------------ adversary

fn cmd_adversary(args: &Args) -> Result<()> {
    let k = args.usize("k", 100)?;
    let s = args.usize("s", 10)?;
    let r = args.usize("r", (k * 4) / 5)?;
    let seed = args.u64("seed", 2017)?;
    let rho = k as f64 / (r as f64 * s as f64);
    let mut rng = Rng::new(seed);

    println!("scheme,strategy,objective,err_optimal");
    for scheme in [Scheme::Frc, Scheme::Bgc, Scheme::Rbgc, Scheme::RegularGraph, Scheme::Cyclic] {
        let g = scheme.build(k, k, s).assignment(&mut rng);
        let report = |strategy: &str, ns: &[usize]| {
            let obj = asp_objective(&g, ns, rho);
            let err = OptimalDecoder::new().err(&g.select_columns(ns));
            println!("{},{strategy},{obj:.6e},{err:.6e}", scheme.name());
        };
        report("random", &rng.sample_indices(k, r));
        report("frc-block-attack", &frc_worst_stragglers(&g, r));
        report("greedy", &greedy_stragglers(&g, r, rho));
        report("local-search", &local_search_stragglers(&g, r, rho, 5));
    }
    Ok(())
}

// ------------------------------------------------------------- ablation

fn cmd_ablation(args: &Args) -> Result<()> {
    use gradcode::sim::ablations;
    let study = args.get("study").unwrap_or("rho");
    let trials = args.usize("trials", 500)?;
    let mc = MonteCarlo::new(trials, args.u64("seed", 2017)?);
    let (k, s) = (args.usize("k", 100)?, args.usize("s", 10)?);

    let pts = match study {
        "rho" => ablations::rho_sweep(
            Scheme::Bgc,
            k,
            s,
            0.25,
            &[0.25, 0.5, 0.75, 1.0, 1.25, 1.5, 2.0],
            &mc,
        ),
        "rbgc" => ablations::rbgc_threshold(
            k,
            s,
            0.25,
            &[(1.0, 1.0), (1.5, 1.0), (2.0, 1.0), (2.0, 1.5), (3.0, 2.0)],
            &mc,
        ),
        "lsqr" => ablations::lsqr_tolerance(Scheme::Bgc, k, s, 0.25, &[1, 2, 4, 8, 16, 64], &mc),
        "normalization" => {
            ablations::normalization(Scheme::Bgc, k, s, &[0.1, 0.3, 0.5], &mc)
        }
        other => bail!("unknown study {other:?} (rho|rbgc|lsqr|normalization)"),
    };
    println!("{}", gradcode::sim::AblationPoint::csv_header());
    for p in pts {
        println!("{}", p.to_csv());
    }
    Ok(())
}

// -------------------------------------------------------------- inspect

fn cmd_inspect(args: &Args) -> Result<()> {
    let manifest = Manifest::load(Manifest::default_dir())?;
    let names: Vec<String> = match args.get("artifact") {
        Some(n) => vec![n.to_string()],
        None => manifest.artifacts.iter().map(|a| a.name.clone()).collect(),
    };
    for name in names {
        let spec = manifest.spec(&name)?;
        let stats = gradcode::runtime::inspect_file(&spec.path)?;
        println!(
            "{name}: module={} computations={} instructions={} entry-params={}",
            stats.module_name, stats.computations, stats.instructions, stats.parameters
        );
        let mut ops: Vec<(&String, &usize)> = stats.opcodes.iter().collect();
        ops.sort_by_key(|&(_, c)| std::cmp::Reverse(*c));
        for (op, count) in ops.iter().take(10) {
            println!("    {op:<24} {count}");
        }
    }
    Ok(())
}

// ----------------------------------------------------------------- demo

fn cmd_demo() -> Result<()> {
    println!("== 1. decoding error at one figure point (k=100, s=5, delta=0.3) ==");
    let mc = MonteCarlo::new(300, 1);
    let cfg = FigureConfig { k: 100, s_values: vec![5], deltas: vec![0.3], mc };
    for p in figures::figure2(&cfg) {
        println!("  one-step {}: err1/k = {:.4}", p.scheme, p.value);
    }
    for p in figures::figure3(&cfg) {
        println!("  optimal  {}: err/k  = {:.4}", p.scheme, p.value);
    }

    println!("== 2. the Thm-10 attack on FRC (k=100, s=10, r=80) ==");
    let mut rng = Rng::new(2);
    let g = Scheme::Frc.build(100, 100, 10).assignment(&mut rng);
    let ns = frc_worst_stragglers(&g, 80);
    let err = OptimalDecoder::new().err(&g.select_columns(&ns));
    println!("  adversarial err = {err} (theory: k - r = 20)");

    println!("== 3. coded training, native backend (k=20, s=5, 25% stragglers) ==");
    let backend = Backend::Native {
        linear: LinearDims { m: 16, d: 16 },
        mlp: MlpDims { m: 8, d_in: 8, d_hidden: 16, d_out: 4, flat_dim: 8 * 16 + 16 + 16 * 4 + 4 },
        s_max: 10,
    };
    let mut cfg = TrainConfig::new(Scheme::Frc, 20, 5, ModelKind::Linear);
    cfg.steps = 30;
    cfg.coordinator.deadline = DeadlinePolicy::FastestR(15);
    let out = train(&backend, &cfg)?;
    println!(
        "  loss {:.4} -> {:.4} over {} rounds with 5/20 stragglers per round",
        out.history.rounds[0].loss,
        out.history.final_loss(),
        out.history.rounds.len()
    );
    println!("demo OK");
    Ok(())
}
