//! `repro` — CLI for the gradcode reproduction (binary name:
//! `gradcode`; run it as `cargo run --release -- <subcommand>`).
//!
//! Arg parsing is hand-rolled (clap is not in the offline vendor set):
//! `--key value` pairs after a subcommand, plus positional file
//! arguments for `merge`. Unknown subcommands and unknown flags are
//! **errors**: the full usage block is printed to stderr and the
//! process exits with status 2 (runtime failures exit 1).
//!
//! Subcommands and every flag default:
//!
//! ```text
//! repro figures    --fig 2          figure to regenerate (2|3|4|5)
//!                  --trials 5000    Monte-Carlo trials per point
//!                  --seed 2017      root RNG seed
//!                  --k 100          tasks/workers k (= n)
//!                  --tmax 15        iterations for --fig 5 curves
//!                  --threads auto   worker threads (results invariant)
//!                  --panel-width 8  decode-panel lanes (results invariant)
//!                  --stragglers uniform  straggler scenario (see below)
//! repro tables     --table thm5     thm3|thm5|thm6|thm8|thm10|thm11|thm21|thm24
//!                  --trials 2000    Monte-Carlo trials per point
//!                  --seed 2017      root RNG seed
//!                  --k 100          tasks/workers k
//!                  --s 10           per-worker load s (thm3/thm5/thm6/thm10
//!                                   only; the other tables derive s and
//!                                   reject the flag)
//!                  --threads auto
//!                  --stragglers uniform  (thm3/thm10/thm11 reject it)
//! repro ablation   --study rho      rho|rbgc|lsqr|normalization
//!                  --trials 500  --seed 2017  --k 100  --s 10
//!                  --threads auto   --stragglers uniform
//! repro scenario   --study tta      tta|tta3|latparam
//!                  --stragglers pareto:0.02,1.5  latency model (required
//!                                   family: shifted-exp|pareto|bimodal)
//!                  --trials 500  --seed 2017  --k 100  --s 10
//!                  --threads auto
//!                                   emits time-to-accuracy curves: mean
//!                                   gather wall-clock vs err1, per
//!                                   scheme, for both deadline-policy
//!                                   arms (fastest-r / fixed quantile
//!                                   deadline) across the delta grid;
//!                                   --study latparam instead fixes the
//!                                   deadline at the base model's 80th
//!                                   percentile and sweeps the latency
//!                                   parameters (Pareto tail index /
//!                                   shifted-exp rate arms)
//! repro shard      --fig F | --table T | --ablation STUDY | --scenario STUDY
//!                  --shard-id I     this shard's index (required, 0-based)
//!                  --num-shards N   total shards (required)
//!                  --out FILE       artifact path (default: stdout)
//!                  (+ the figures/tables/ablation/scenario flags above;
//!                   --trials defaults to 5000 for figures, 2000 for
//!                   tables, 500 for ablations and scenarios)
//! repro run        --fig F | --table T | --ablation STUDY | --scenario STUDY
//!                  --fanout 2       spawn N `repro shard` processes
//!                                   locally, wait, verify, merge, and
//!                                   emit the unsharded-identical CSV
//!                  --artifacts-dir DIR  keep the shard artifacts there
//!                                   (default: a temp dir, removed)
//!                  --resume DIR     reuse the valid artifacts already in
//!                                   DIR and respawn only missing/corrupt
//!                                   shards (implies keeping artifacts)
//!                  (+ the same job flags as `repro shard`; without
//!                   --threads each child gets cores/fanout workers so
//!                   the fan-out never oversubscribes the machine)
//! repro serve      --addr 127.0.0.1:7117  bind address (port 0 =
//!                                   ephemeral; the bound address is
//!                                   printed as `listening on ADDR`)
//!                  --serve-threads reactor  reactor|legacy session loop
//!                                   decode/experiment-job daemon:
//!                                   length-prefixed JSON frames with
//!                                   hot per-connection decode
//!                                   workspaces, memoized standing
//!                                   assignments, the fan-out job
//!                                   scheduler (`job` requests), and
//!                                   HTTP GET /metrics counters on the
//!                                   same port; the default reactor is
//!                                   an epoll event loop answering
//!                                   pipelined requests in completion
//!                                   order and draining in-flight work
//!                                   on shutdown; legacy keeps the old
//!                                   thread-per-connection loop
//! repro load       --addr 127.0.0.1:7117  daemon to fire at
//!                  --requests 64    total decode requests
//!                  --concurrency 4  persistent connections
//!                  --pipeline 1     requests in flight per connection
//!                                   (replies matched by echoed id)
//!                  --workload fixed fixed | latparam (cycle the latparam
//!                                   study's 108-template grid; base
//!                                   model from --stragglers, default
//!                                   pareto:0.02,1.5)
//!                  --arrival closed closed | uniform:GAP_MS | poisson:RATE
//!                  --seed 2017      root seed: derives every request
//!                                   seed, so the stdout replay CSV is
//!                                   byte-identical per seed at any
//!                                   concurrency/arrival/pipeline
//!                                   setting
//!                  --scheme frc --k 100 --n K --s 10 --delta 0.2
//!                  --r (1-delta)*n  survivors per decode round
//!                  --rounds 8       decode rounds per request
//!                  --decoder onestep onestep|optimal
//!                  --slo-ms 0       p99 SLO in ms (0 = report only;
//!                                   otherwise FAIL exits 1)
//! repro merge      FILE...          shard artifacts; emits the same CSV
//!                                   as the unsharded run, bit-for-bit
//!                  --out FILE       instead fold the (possibly
//!                                   incomplete, disjoint) set into one
//!                                   compound partial artifact — the
//!                                   tree-reduction step ("-" = stdout)
//! repro verify     FILE...          audit an artifact set without
//!                                   merging: checksums, same job,
//!                                   disjoint complete shard coverage,
//!                                   per-artifact trial accounting
//! repro train      --scheme frc     frc|bgc|rbgc|regular|cyclic
//!                  --model linear   linear|mlp
//!                  --decoder onestep onestep|optimal
//!                  --k 100  --s 10  --steps 200  --delta 0.2  --lr 0.5
//!                  --backend pjrt   pjrt|native
//!                  --engines 2      PJRT engine pool size
//!                  --seed 0
//! repro adversary  --k 100  --s 10  --r 80 (= 4k/5)  --seed 2017
//! repro inspect    --artifact NAME  (default: every manifest entry)
//! repro demo
//! repro help
//! ```
//!
//! The `--stragglers` grammar (the straggler *scenario*, part of the
//! run identity and the v3 shard-artifact format):
//!
//! ```text
//! uniform                       the paper default (δ from the sweep)
//! uniform:D                     fixed straggler fraction D
//!                               (survivors: r = (1-D)k)
//! shifted-exp:BASE,RATE[,P]     latency draws base + Exp(rate)
//! pareto:SCALE,SHAPE[,P]        heavy-tailed Pareto latencies
//! bimodal:FAST,SLOW,PSLOW[,P]   two-mode (clone-straggler) latencies
//! adversarial:block|greedy|local-search   §4 standing-assignment attack
//! P = fastest-r (default) | deadline:T
//! ```
//!
//! The `shard`/`merge` pair distributes a figure/table/ablation/
//! scenario run across processes or machines: each shard runs a
//! disjoint trial range and writes exact partial aggregates as JSON;
//! `merge` validates the partition and reproduces the unsharded CSV
//! bit-for-bit. `merge --out` folds any disjoint subset into a
//! compound artifact (enabling tree-reduction over thousands of
//! shards), `verify` audits an artifact set without merging, and
//! `run --fanout N` drives the whole shard → verify → merge cycle as
//! one local command — resumably, with `--resume DIR` (see `sim::shard`
//! and ARCHITECTURE.md).

use anyhow::Context;

use gradcode::adversary::{
    asp_objective, frc_worst_stragglers, greedy_stragglers, local_search_stragglers,
};
use gradcode::codes::Scheme;
use gradcode::coordinator::{DecoderKind, ModelKind};
use gradcode::decode::OptimalDecoder;
use gradcode::load::{run_load, Arrival, LoadConfig, Workload};
use gradcode::runtime::{Backend, EnginePool, LinearDims, Manifest, MlpDims};
use gradcode::serve::{
    run_fanout, serve, ArtifactDir, DecodeRequest, FanoutPlan, ServeConfig, SessionLoop,
};
use gradcode::sim::shard::{
    ABLATION_IDS, SCENARIO_IDS, TABLES_WITHOUT_SCENARIO, TABLES_WITH_S, TABLE_IDS,
};
use gradcode::sim::{
    figures, tta_anytime, AnytimeRules, FigureConfig, JobKind, JobSpec, MonteCarlo,
    ScenarioPoint, Shard, ShardArtifact,
};
use gradcode::stragglers::{DeadlinePolicy, LatencyModel, PolicySpec, Scenario};
use gradcode::training::{train, TrainConfig};
use gradcode::util::Rng;

/// CLI failure modes: usage errors reprint the help block and exit 2;
/// runtime errors exit 1.
#[derive(Debug)]
enum CliError {
    Usage(String),
    Runtime(anyhow::Error),
}

impl From<anyhow::Error> for CliError {
    fn from(e: anyhow::Error) -> Self {
        CliError::Runtime(e)
    }
}

type CliResult<T = ()> = Result<T, CliError>;

fn usage<T>(msg: impl Into<String>) -> CliResult<T> {
    Err(CliError::Usage(msg.into()))
}

/// Tiny argv parser: `--key value` pairs plus positional arguments
/// after a subcommand.
struct Args {
    sub: String,
    kv: Vec<(String, String)>,
    positional: Vec<String>,
}

impl Args {
    fn parse() -> CliResult<Args> {
        let mut it = std::env::args().skip(1);
        let sub = it.next().unwrap_or_else(|| "help".to_string());
        let mut kv = Vec::new();
        let mut positional = Vec::new();
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                let Some(val) = it.next() else {
                    return usage(format!("--{key} needs a value"));
                };
                kv.push((key.to_string(), val));
            } else {
                positional.push(tok);
            }
        }
        Ok(Args { sub, kv, positional })
    }

    /// Reject flags the subcommand does not define, and positional
    /// arguments unless the subcommand takes them.
    fn finish(&self, allowed: &[&str], allow_positional: bool) -> CliResult<()> {
        for (k, _) in &self.kv {
            if !allowed.contains(&k.as_str()) {
                let hint = if allowed.is_empty() {
                    "takes no flags".to_string()
                } else {
                    format!("allowed: --{}", allowed.join(", --"))
                };
                return usage(format!("unknown flag --{k} for `repro {}` ({hint})", self.sub));
            }
        }
        if !allow_positional && !self.positional.is_empty() {
            return usage(format!(
                "`repro {}` takes no positional arguments (got {:?})",
                self.sub, self.positional
            ));
        }
        Ok(())
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.kv.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    fn usize(&self, key: &str, default: usize) -> CliResult<usize> {
        match self.get(key) {
            Some(v) => match v.parse::<usize>() {
                Ok(x) => Ok(x),
                Err(_) => usage(format!("--{key} {v:?}: expected a non-negative integer")),
            },
            None => Ok(default),
        }
    }

    fn f64(&self, key: &str, default: f64) -> CliResult<f64> {
        match self.get(key) {
            Some(v) => match v.parse::<f64>() {
                Ok(x) => Ok(x),
                Err(_) => usage(format!("--{key} {v:?}: expected a number")),
            },
            None => Ok(default),
        }
    }

    fn u64(&self, key: &str, default: u64) -> CliResult<u64> {
        match self.get(key) {
            Some(v) => match v.parse::<u64>() {
                Ok(x) => Ok(x),
                Err(_) => usage(format!("--{key} {v:?}: expected a non-negative integer")),
            },
            None => Ok(default),
        }
    }
}

fn main() {
    let code = match run() {
        Ok(()) => 0,
        Err(CliError::Usage(msg)) => {
            eprintln!("error: {msg}");
            eprintln!();
            eprint!("{HELP}");
            2
        }
        Err(CliError::Runtime(e)) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run() -> CliResult<()> {
    let args = Args::parse()?;
    match args.sub.as_str() {
        "figures" => {
            args.finish(
                &["fig", "trials", "seed", "k", "tmax", "threads", "panel-width", "stragglers"],
                false,
            )?;
            cmd_figures(&args)
        }
        "tables" => {
            args.finish(
                &["table", "trials", "seed", "k", "s", "threads", "panel-width", "stragglers"],
                false,
            )?;
            cmd_tables(&args)
        }
        "scenario" => {
            args.finish(
                &[
                    "stragglers", "study", "trials", "seed", "k", "s", "threads",
                    "target-err", "revise-at", "revise-to",
                ],
                false,
            )?;
            cmd_scenario(&args)
        }
        "shard" => {
            // The job-specific flags mirror `figures`/`tables`/
            // `ablation`/`scenario`: --tmax only makes sense for figure
            // jobs and --s only for table/ablation/scenario jobs;
            // whitelisting both unconditionally would silently ignore
            // the wrong one instead of exiting 2.
            let mut allowed = vec![
                "fig", "table", "ablation", "scenario", "trials", "seed", "k", "shard-id",
                "num-shards", "out", "threads", "panel-width", "stragglers",
            ];
            if args.get("fig").is_some() {
                allowed.push("tmax");
            }
            if args.get("table").is_some()
                || args.get("ablation").is_some()
                || args.get("scenario").is_some()
            {
                allowed.push("s");
            }
            args.finish(&allowed, false)?;
            cmd_shard(&args)
        }
        "run" => {
            // Same conditional job flags as `shard`, plus the driver's.
            let mut allowed = vec![
                "fig", "table", "ablation", "scenario", "fanout", "trials", "seed", "k",
                "artifacts-dir", "resume", "threads", "panel-width", "stragglers",
            ];
            if args.get("fig").is_some() {
                allowed.push("tmax");
            }
            if args.get("table").is_some()
                || args.get("ablation").is_some()
                || args.get("scenario").is_some()
            {
                allowed.push("s");
            }
            args.finish(&allowed, false)?;
            cmd_run(&args)
        }
        "serve" => {
            args.finish(&["addr", "panel-width", "serve-threads"], false)?;
            cmd_serve(&args)
        }
        "load" => {
            args.finish(
                &[
                    "addr", "requests", "concurrency", "pipeline", "arrival", "seed", "scheme",
                    "k", "n", "s", "delta", "r", "rounds", "decoder", "prefix", "slo-ms",
                    "workload", "stragglers",
                ],
                false,
            )?;
            cmd_load(&args)
        }
        "merge" => {
            args.finish(&["out"], true)?;
            cmd_merge(&args)
        }
        "verify" => {
            args.finish(&[], true)?;
            cmd_verify(&args)
        }
        "train" => {
            args.finish(
                &[
                    "scheme", "model", "decoder", "k", "s", "steps", "delta", "lr", "backend",
                    "engines", "seed",
                ],
                false,
            )?;
            cmd_train(&args)
        }
        "adversary" => {
            args.finish(&["k", "s", "r", "seed"], false)?;
            cmd_adversary(&args)
        }
        "ablation" => {
            args.finish(&["study", "trials", "seed", "k", "s", "threads", "stragglers"], false)?;
            cmd_ablation(&args)
        }
        "inspect" => {
            args.finish(&["artifact"], false)?;
            cmd_inspect(&args)
        }
        "demo" => {
            args.finish(&[], false)?;
            cmd_demo()
        }
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        other => usage(format!("unknown subcommand {other:?}")),
    }
}

const HELP: &str = "\
repro — Approximate Gradient Coding via Sparse Random Graphs (2017)

USAGE:
  repro figures --fig 2|3|4|5 [--trials N] [--k K] [--seed S] [--tmax T]
                [--threads T] [--panel-width W] [--stragglers SPEC]
  repro tables  --table thm3|thm5|thm6|thm8|thm10|thm11|thm21|thm24
                [--trials N] [--k K] [--s S] [--seed S] [--threads T]
                [--panel-width W] [--stragglers SPEC]
  repro ablation --study rho|rbgc|lsqr|normalization [--trials N] [--k K]
                [--s S] [--seed S] [--threads T] [--stragglers SPEC]
  repro scenario [--study tta|tta3|latparam] [--stragglers SPEC] [--trials N]
                [--k K] [--s S] [--seed S] [--threads T]
                [--target-err E] [--revise-at T --revise-to T]
                                    # time-to-accuracy curves: mean
                                    # gather wall-clock vs err1 per
                                    # scheme, fastest-r and fixed-
                                    # deadline arms across the delta
                                    # grid (SPEC must be a latency
                                    # model); --study tta3 adds the
                                    # optimal (LSQR) decoder as a third
                                    # arm on the fastest-r draws; the
                                    # anytime flags (tta only) stream
                                    # each trial through the
                                    # incremental decoder and stop
                                    # early: --target-err cancels at
                                    # the first arrival with err1/k <=
                                    # E, --revise-at/--revise-to
                                    # shorten the deadline mid-round;
                                    # --study latparam fixes the
                                    # deadline (base 80th percentile)
                                    # and sweeps the latency-model
                                    # parameters instead: Pareto tail
                                    # index and shifted-exp rate arms
  repro shard   --fig F|--table T|--ablation STUDY|--scenario STUDY
                --shard-id I --num-shards N [--out FILE] [--trials N]
                [--k K] [--s S] [--seed S] [--tmax T] [--threads T]
                [--panel-width W] [--stragglers SPEC]
  repro run     --fig F|--table T|--ablation STUDY|--scenario STUDY
                [--fanout N] [--artifacts-dir DIR | --resume DIR]
                [--trials N] [--k K] [--s S] [--seed S] [--tmax T]
                [--threads T] [--panel-width W] [--stragglers SPEC]
                                    # spawn N shard processes, wait,
                                    # verify, merge -> CSV on stdout;
                                    # --resume reuses DIR's valid
                                    # artifacts and respawns only the
                                    # missing/corrupt shards
  repro serve   [--addr ADDR] [--panel-width W]
                [--serve-threads reactor|legacy]
                                    # decode/experiment-job daemon:
                                    # length-prefixed JSON frames, hot
                                    # per-connection decode workspaces,
                                    # memoized standing assignments, a
                                    # shared fan-out job scheduler, and
                                    # HTTP GET /metrics counters on the
                                    # same port; {\"cmd\":\"shutdown\"}
                                    # drains in-flight requests and
                                    # stops it; the default reactor is
                                    # an epoll event loop (pipelined
                                    # requests answered in completion
                                    # order), legacy the old thread-
                                    # per-connection loop
  repro load    [--addr ADDR] [--requests N] [--concurrency C]
                [--pipeline D] [--workload fixed|latparam]
                [--arrival closed|uniform:GAP_MS|poisson:RATE] [--seed S]
                [--scheme S] [--k K] [--n N] [--s S] [--delta D] [--r R]
                [--rounds N] [--decoder onestep|optimal] [--prefix P]
                [--slo-ms MS] [--stragglers SPEC]
                                    # --prefix P decodes only the first
                                    # P arrivals of each round (anytime
                                    # decode at the server)
                                    # seeded deterministic traffic
                                    # generator: replay CSV on stdout is
                                    # byte-identical per seed (any
                                    # concurrency/arrival/pipeline
                                    # depth); --pipeline D keeps D
                                    # requests in flight per connection
                                    # (replies matched by echoed id);
                                    # --workload latparam cycles the
                                    # latparam study's template grid
                                    # (base model from --stragglers);
                                    # latency p50/p99/p999 + throughput
                                    # report on stderr; --slo-ms gates
                                    # the exit status on the p99 target
  repro merge   FILE... [--out FILE]  # merge artifacts -> CSV on stdout;
                                    # with --out, fold any disjoint
                                    # subset into one partial artifact
  repro verify  FILE...             # audit an artifact set (checksums,
                                    # same job, disjoint complete
                                    # coverage) without merging
  repro train   [--scheme S] [--model linear|mlp] [--decoder onestep|optimal]
                [--k K] [--s S] [--steps N] [--delta D] [--lr LR]
                [--backend pjrt|native] [--engines E] [--seed S]
  repro adversary [--k K] [--s S] [--r R] [--seed S]
  repro inspect   [--artifact NAME]     # HLO stats of an AOT artifact
  repro demo
  repro help

STRAGGLER SCENARIOS (--stragglers SPEC; part of the run identity):
  uniform                      paper default: r=(1-d)k uniform survivors
  uniform:D                    fixed straggler fraction D (r = (1-D)k)
  shifted-exp:BASE,RATE[,P]    latency draws base + Exp(rate)
  pareto:SCALE,SHAPE[,P]       heavy-tailed Pareto latencies
  bimodal:FAST,SLOW,PSLOW[,P]  two-mode (clone-straggler) latencies
  adversarial:block|greedy|local-search   standing-assignment attack
  P = fastest-r (default) | deadline:T
  The default uniform scenario reproduces every published CSV
  byte-for-byte; thm3/thm10/thm11 reject non-uniform scenarios.

DEFAULTS:
  figures: --fig 2 --trials 5000 --seed 2017 --k 100 --tmax 15
  tables:  --table thm5 --trials 2000 --seed 2017 --k 100 --s 10
  ablation: --study rho --trials 500 --seed 2017 --k 100 --s 10
  scenario: --study tta --stragglers pareto:0.02,1.5 --trials 500
           --seed 2017 --k 100 --s 10
  shard:   figures/tables/ablation/scenario defaults above; --out - (stdout)
  run:     shard defaults above; --fanout 2; --artifacts-dir <temp dir>
           (temporary artifacts are removed after the merge); each child
           gets --threads cores/fanout unless --threads is given
  serve:   --addr 127.0.0.1:7117 (port 0 picks an ephemeral port; the
           bound address is printed as `listening on ADDR`)
  load:    --addr 127.0.0.1:7117 --requests 64 --concurrency 4
           --arrival closed --seed 2017 --scheme frc --k 100 --n K --s 10
           --delta 0.2 --r (1-delta)*n --rounds 8 --decoder onestep
           --slo-ms 0 (0 = no SLO verdict)
  train:   --scheme frc --model linear --decoder onestep --k 100 --s 10
           --steps 200 --delta 0.2 --lr 0.5 --backend pjrt --engines 2 --seed 0
  adversary: --k 100 --s 10 --r 4k/5 --seed 2017
  --stragglers defaults to uniform everywhere but `repro scenario`.
  --threads defaults to the machine's core count (capped at 16); results
  are bit-identical for every thread count.
  --panel-width defaults to 8 lanes per panel decode sweep; results are
  bit-identical at every width (each lane replays its trial's exact RNG
  fork). 0 and widths above 4096 are usage errors; the flag is an
  execution hint only and never enters the shard artifacts.

SHARDING:
  `repro shard` runs one disjoint slice of a figure/table/ablation/
  scenario's trial range and writes exact partial aggregates as a
  checksummed JSON artifact; `repro merge` over a complete shard set
  reproduces the unsharded CSV bit-for-bit, and `repro run --fanout N`
  drives the whole cycle (spawn, wait, verify, merge) as one command:

    repro run --fig 3 --fanout 4 > fig3.csv

  An interrupted fan-out resumes without recomputing finished shards:

    repro run --fig 3 --fanout 8 --artifacts-dir fig3_shards   # killed
    repro run --fig 3 --fanout 8 --resume fig3_shards > fig3.csv

  For multi-machine runs, fan out by hand and reduce as a tree —
  `merge --out` folds any disjoint subset into a compound artifact:

    repro shard --fig 3 --shard-id 0 --num-shards 8 --out fig3_0.json
    ... (shards 1-7, on any mix of machines) ...
    repro merge fig3_0.json ... fig3_3.json --out fig3_lo.json
    repro merge fig3_4.json ... fig3_7.json --out fig3_hi.json
    repro verify fig3_lo.json fig3_hi.json
    repro merge fig3_lo.json fig3_hi.json > fig3.csv

Exit status: 0 on success, 1 on runtime failure, 2 on usage errors
(unknown subcommand/flag, bad flag value).
";

// -------------------------------------------------------------- figures

fn threads_flag(args: &Args) -> CliResult<Option<usize>> {
    Ok(match args.get("threads") {
        Some(_) => Some(args.usize("threads", 0)?.max(1)),
        None => None,
    })
}

/// The `--panel-width W` execution hint: how many Monte-Carlo trials
/// the panel decode kernels batch per lane-strided sweep. Pure
/// wall-clock knob — every lane replays its trial's exact RNG fork, so
/// the output bits are invariant in W and the flag never enters the job
/// identity or the shard artifacts. W = 0 (no lanes) and absurd widths
/// (the panel buffers scale with W) are usage errors.
fn panel_width_flag(args: &Args) -> CliResult<Option<usize>> {
    match args.get("panel-width") {
        None => Ok(None),
        Some(v) => {
            let w = match v.parse::<usize>() {
                Ok(x) => x,
                Err(_) => {
                    return usage(format!("--panel-width {v:?}: expected a positive integer"))
                }
            };
            if w == 0 {
                return usage("--panel-width 0: the panel needs at least one lane");
            }
            if w > 4096 {
                return usage(format!(
                    "--panel-width {w}: width out of range [1, 4096] (panel workspace \
                     buffers scale with W)"
                ));
            }
            Ok(Some(w))
        }
    }
}

/// The straggler scenario named by `--stragglers` (default: the
/// uniform model every published figure/table uses — byte-identical
/// output to the pre-scenario CLI).
fn stragglers_flag(args: &Args) -> CliResult<Scenario> {
    match args.get("stragglers") {
        None => Ok(Scenario::default()),
        Some(spec) => match Scenario::parse(spec) {
            Ok(s) => Ok(s),
            Err(e) => usage(format!("--stragglers {spec:?}: {e:#}")),
        },
    }
}

fn cmd_figures(args: &Args) -> CliResult<()> {
    let job = figure_job(args)?;
    let points = job.run_hinted(Shard::full(), threads_flag(args)?, panel_width_flag(args)?)?;
    print!("{}", points.to_csv());
    Ok(())
}

fn figure_job(args: &Args) -> CliResult<JobSpec> {
    let fig = args.usize("fig", 2)?;
    if !(2..=5).contains(&fig) {
        return usage(format!("unknown figure {fig} (paper has figures 2-5)"));
    }
    if fig != 5 && args.get("tmax").is_some() {
        return usage(format!(
            "--tmax only applies to --fig 5 (figure {fig} has no iteration axis)"
        ));
    }
    Ok(JobSpec {
        kind: JobKind::Figure,
        id: fig.to_string(),
        trials: args.usize("trials", 5000)?,
        seed: args.u64("seed", 2017)?,
        k: args.usize("k", 100)?,
        s: 0,
        tmax: args.usize("tmax", 15)?,
        scenario: stragglers_flag(args)?,
    })
}

// --------------------------------------------------------------- tables

fn cmd_tables(args: &Args) -> CliResult<()> {
    let job = table_job(args)?;
    let points = job.run_hinted(Shard::full(), threads_flag(args)?, panel_width_flag(args)?)?;
    print!("{}", points.to_csv());
    Ok(())
}

fn table_job(args: &Args) -> CliResult<JobSpec> {
    let table = args.get("table").unwrap_or("thm5");
    if !TABLE_IDS.contains(&table) {
        return usage(format!("unknown table {table:?} (one of {})", TABLE_IDS.join("|")));
    }
    // Accepting --s for a derived-s table would silently run a
    // different sweep than the user asked for.
    if !TABLES_WITH_S.contains(&table) && args.get("s").is_some() {
        return usage(format!("--s is not accepted for --table {table} (s is derived internally)"));
    }
    let scenario = stragglers_flag(args)?;
    if !scenario.is_default() && TABLES_WITHOUT_SCENARIO.contains(&table) {
        return usage(format!(
            "--stragglers is not supported for --table {table} \
             (no uniform straggler sampling to replace)"
        ));
    }
    Ok(JobSpec {
        kind: JobKind::Table,
        id: table.to_string(),
        trials: args.usize("trials", 2000)?,
        seed: args.u64("seed", 2017)?,
        k: args.usize("k", 100)?,
        s: args.usize("s", 10)?,
        tmax: 0,
        scenario,
    })
}

// ------------------------------------------------------------ ablation

fn ablation_job(args: &Args) -> CliResult<JobSpec> {
    // `repro ablation` spells the study --study; `repro shard` and
    // `repro run` spell it --ablation (mirroring --fig/--table).
    let study = args.get("ablation").or(args.get("study")).unwrap_or("rho");
    if !ABLATION_IDS.contains(&study) {
        return usage(format!("unknown study {study:?} (one of {})", ABLATION_IDS.join("|")));
    }
    Ok(JobSpec {
        kind: JobKind::Ablation,
        id: study.to_string(),
        trials: args.usize("trials", 500)?,
        seed: args.u64("seed", 2017)?,
        k: args.usize("k", 100)?,
        s: args.usize("s", 10)?,
        tmax: 0,
        scenario: stragglers_flag(args)?,
    })
}

fn cmd_ablation(args: &Args) -> CliResult<()> {
    let job = ablation_job(args)?;
    let points = job.run(Shard::full(), threads_flag(args)?)?;
    print!("{}", points.to_csv());
    Ok(())
}

// ------------------------------------------------------------ scenario

/// The scenario (time-to-accuracy) job: `repro scenario` and the
/// `--scenario STUDY` kind flag of `repro shard`/`repro run`. Requires
/// a latency straggler model — uniform and adversarial scenarios have
/// no wall-clock axis — with the default (fastest-r) policy: the sweep
/// derives both deadline-policy arms itself.
fn scenario_job(args: &Args) -> CliResult<JobSpec> {
    // `repro scenario --study X` and `repro shard/run --scenario X`
    // name the same registry (the `ablation`/`--study` convention).
    let study = args.get("scenario").or(args.get("study")).unwrap_or("tta");
    if !SCENARIO_IDS.contains(&study) {
        return usage(format!(
            "unknown scenario study {study:?} (one of {})",
            SCENARIO_IDS.join("|")
        ));
    }
    let scenario = match args.get("stragglers") {
        // The coordinator's default cluster model: heavy-tailed Pareto.
        None => Scenario::parse("pareto:0.02,1.5").expect("default scenario spec parses"),
        Some(_) => stragglers_flag(args)?,
    };
    match &scenario {
        Scenario::Latency { policy: PolicySpec::FastestR, .. } => {}
        Scenario::Latency { .. } => {
            return usage(
                "the scenario job sweeps the deadline axis itself; drop the explicit \
                 deadline:T policy from --stragglers",
            );
        }
        _ => {
            return usage(
                "`repro scenario` needs a latency straggler model: \
                 --stragglers shifted-exp:BASE,RATE | pareto:SCALE,SHAPE | bimodal:FAST,SLOW,P",
            );
        }
    }
    Ok(JobSpec {
        kind: JobKind::Scenario,
        id: study.to_string(),
        trials: args.usize("trials", 500)?,
        seed: args.u64("seed", 2017)?,
        k: args.usize("k", 100)?,
        s: args.usize("s", 10)?,
        tmax: 0,
        scenario,
    })
}

/// Anytime stopping rules from the `repro scenario` flags. CLI-only:
/// the rules change what a trial measures, so they are not part of the
/// shardable job identity (`repro shard`/`repro run` reject them at
/// the flag whitelist).
fn anytime_rules_flags(args: &Args) -> CliResult<AnytimeRules> {
    let target_err1 = match args.get("target-err") {
        None => None,
        Some(_) => {
            let t = args.f64("target-err", 0.0)?;
            if !t.is_finite() || t < 0.0 {
                return usage(format!(
                    "--target-err {t}: expected a finite non-negative err1/k target"
                ));
            }
            Some(t)
        }
    };
    let revise = match (args.get("revise-at"), args.get("revise-to")) {
        (None, None) => None,
        (Some(_), Some(_)) => {
            let at = args.f64("revise-at", 0.0)?;
            let to = args.f64("revise-to", 0.0)?;
            if !(at.is_finite() && to.is_finite() && at >= 0.0 && to >= 0.0) {
                return usage(
                    "--revise-at/--revise-to: expected finite non-negative wall-clock times",
                );
            }
            Some((at, to))
        }
        _ => return usage("--revise-at and --revise-to must be given together"),
    };
    Ok(AnytimeRules { target_err1, revise })
}

fn cmd_scenario(args: &Args) -> CliResult<()> {
    let rules = anytime_rules_flags(args)?;
    let job = scenario_job(args)?;
    if rules.is_empty() {
        let points = job.run(Shard::full(), threads_flag(args)?)?;
        print!("{}", points.to_csv());
        return Ok(());
    }
    if job.id != "tta" {
        return usage(
            "anytime rules (--target-err/--revise-at/--revise-to) apply to the one-step \
             `tta` arms only; drop --study tta3|latparam",
        );
    }
    let mut mc = MonteCarlo::new(job.trials, job.seed);
    if let Some(t) = threads_flag(args)? {
        mc = mc.with_threads(t);
    }
    let points = tta_anytime(job.k, job.s, &job.scenario, &mc, rules)?;
    let mut out = String::new();
    out.push_str(ScenarioPoint::csv_header());
    out.push('\n');
    for p in &points {
        out.push_str(&p.to_csv());
        out.push('\n');
    }
    print!("{out}");
    Ok(())
}

// ----------------------------------------- shard / run / merge / verify

/// The job named by exactly one of --fig / --table / --ablation /
/// --scenario (shared by `repro shard` and `repro run`).
fn job_from_kind_flags(args: &Args, cmd: &str) -> CliResult<JobSpec> {
    match (
        args.get("fig"),
        args.get("table"),
        args.get("ablation"),
        args.get("scenario"),
    ) {
        (Some(_), None, None, None) => figure_job(args),
        (None, Some(_), None, None) => table_job(args),
        (None, None, Some(_), None) => ablation_job(args),
        (None, None, None, Some(_)) => scenario_job(args),
        (None, None, None, None) => usage(format!(
            "`repro {cmd}` needs --fig F, --table T, --ablation STUDY, or --scenario STUDY"
        )),
        _ => usage(format!(
            "pass exactly one of --fig / --table / --ablation / --scenario to `repro {cmd}`"
        )),
    }
}

fn cmd_shard(args: &Args) -> CliResult<()> {
    let job = job_from_kind_flags(args, "shard")?;
    let Some(shard_id) = args.get("shard-id") else {
        return usage("`repro shard` needs --shard-id I (0-based)");
    };
    let Some(num_shards) = args.get("num-shards") else {
        return usage("`repro shard` needs --num-shards N");
    };
    let shard_id = match shard_id.parse::<usize>() {
        Ok(x) => x,
        Err(_) => return usage(format!("--shard-id {shard_id:?}: expected an integer")),
    };
    let num_shards = match num_shards.parse::<usize>() {
        Ok(x) => x,
        Err(_) => return usage(format!("--num-shards {num_shards:?}: expected an integer")),
    };
    let shard = match Shard::new(shard_id, num_shards) {
        Ok(s) => s,
        Err(e) => return usage(format!("{e}")),
    };

    let artifact =
        ShardArtifact::compute_hinted(&job, shard, threads_flag(args)?, panel_width_flag(args)?)?;
    let text = artifact.to_json_string();
    match args.get("out") {
        Some("-") | None => print!("{text}"),
        Some(path) => {
            std::fs::write(path, &text).with_context(|| format!("writing {path}"))?;
            eprintln!(
                "wrote shard {}/{} of {} {} ({} points) to {path}",
                shard_id,
                num_shards,
                job.kind.name(),
                job.id,
                artifact.points.len()
            );
        }
    }
    Ok(())
}

/// `repro run --fanout N`: the local fan-out driver. A thin
/// flag-parsing shim over [`gradcode::serve::run_fanout`] — the same
/// scheduler the `repro serve` daemon uses for `job` requests — which
/// spawns N `repro shard` child processes of this same binary, waits,
/// verifies the artifact set, merges, and prints the
/// unsharded-identical CSV. With `--resume DIR`, valid artifacts
/// already in DIR are reused and only the missing/corrupt shards are
/// respawned; a *non-resume* run pointed at a directory that already
/// holds artifacts is refused (stale shards would silently mix into
/// the fresh verify/merge set).
fn cmd_run(args: &Args) -> CliResult<()> {
    let job = job_from_kind_flags(args, "run")?;
    let fanout = args.usize("fanout", 2)?;
    if fanout == 0 {
        return usage("--fanout must be at least 1");
    }
    if args.get("artifacts-dir").is_some() && args.get("resume").is_some() {
        return usage(
            "pass either --artifacts-dir or --resume (a resumed run reuses and keeps \
             the artifacts in its --resume directory)",
        );
    }
    let exe = std::env::current_exe().context("locating the running binary")?;
    let dir = match (args.get("resume"), args.get("artifacts-dir")) {
        (Some(d), _) => ArtifactDir::Resume(std::path::PathBuf::from(d)),
        (None, Some(d)) => ArtifactDir::Keep(std::path::PathBuf::from(d)),
        (None, None) => ArtifactDir::Temp,
    };
    let plan = FanoutPlan {
        job,
        fanout,
        dir,
        threads: threads_flag(args)?,
        panel_width: panel_width_flag(args)?,
    };
    let merged = run_fanout(&exe, &plan)?;
    print!("{}", merged.to_csv());
    Ok(())
}

// --------------------------------------------------------- serve / load

/// `repro serve`: run the decode/experiment-job daemon until a
/// `shutdown` frame arrives. Prints `listening on ADDR` to stdout once
/// bound (`--addr` port 0 picks an ephemeral port), then speaks
/// length-prefixed JSON frames — plus HTTP `GET /metrics` on the same
/// port — until shut down. See `gradcode::serve` for the protocol.
fn cmd_serve(args: &Args) -> CliResult<()> {
    let loop_name = args.get("serve-threads").unwrap_or("reactor");
    let Some(session_loop) = SessionLoop::parse(loop_name) else {
        return usage(format!("unknown --serve-threads {loop_name:?} (reactor|legacy)"));
    };
    let cfg = ServeConfig {
        addr: args.get("addr").unwrap_or("127.0.0.1:7117").to_string(),
        exe: std::env::current_exe().context("locating the running binary")?,
        panel_width: panel_width_flag(args)?,
        session_loop,
    };
    serve(&cfg)?;
    Ok(())
}

/// `repro load`: fire a seeded, deterministic decode workload at a
/// running daemon. The replay CSV (stdout) is byte-identical for a
/// given `--seed` and request template, independent of `--concurrency`
/// and `--arrival`; the latency/throughput report goes to stderr. A
/// configured `--slo-ms` p99 target turns the exit status into the SLO
/// verdict (0 = PASS, 1 = FAIL).
fn cmd_load(args: &Args) -> CliResult<()> {
    let requests = args.usize("requests", 64)?;
    if requests == 0 {
        return usage("--requests must be at least 1");
    }
    let concurrency = args.usize("concurrency", 4)?;
    if concurrency == 0 {
        return usage("--concurrency must be at least 1");
    }
    let pipeline = args.usize("pipeline", 1)?;
    if !(1..=1024).contains(&pipeline) {
        return usage(format!("--pipeline {pipeline} out of range [1, 1024]"));
    }
    let arrival_spec = args.get("arrival").unwrap_or("closed");
    let arrival = match Arrival::parse(arrival_spec) {
        Ok(a) => a,
        Err(e) => return usage(format!("--arrival {arrival_spec:?}: {e:#}")),
    };
    let scheme_name = args.get("scheme").unwrap_or("frc");
    let Some(scheme) = Scheme::parse(scheme_name) else {
        return usage(format!("unknown scheme {scheme_name:?}"));
    };
    let k = args.usize("k", 100)?;
    if k == 0 {
        return usage("--k must be at least 1");
    }
    let n = args.usize("n", k)?;
    if n == 0 {
        return usage("--n must be at least 1");
    }
    let s = args.usize("s", 10)?;
    if !(1..=k).contains(&s) {
        return usage(format!("--s {s} out of range [1, {k}]"));
    }
    let delta = args.f64("delta", 0.2)?;
    if !(0.0..1.0).contains(&delta) {
        return usage(format!("--delta {delta} out of range [0, 1)"));
    }
    let r_default = (((1.0 - delta) * n as f64).round() as usize).clamp(1, n);
    let r = args.usize("r", r_default)?;
    if !(1..=n).contains(&r) {
        return usage(format!("--r {r} out of range [1, {n}]"));
    }
    let rounds = args.usize("rounds", 8)?;
    if rounds == 0 {
        return usage("--rounds must be at least 1");
    }
    let decoder_name = args.get("decoder").unwrap_or("onestep");
    let Some(decoder) = DecoderKind::parse(decoder_name) else {
        return usage(format!("unknown decoder {decoder_name:?} (onestep|optimal)"));
    };
    let prefix = match args.get("prefix") {
        None => None,
        Some(_) => {
            let p = args.usize("prefix", r)?;
            if !(1..=r).contains(&p) {
                return usage(format!("--prefix {p} out of range [1, {r}]"));
            }
            Some(p)
        }
    };
    let seed = args.u64("seed", 2017)?;
    let workload = match args.get("workload").unwrap_or("fixed") {
        "fixed" => Workload::Fixed,
        "latparam" => {
            // The latparam grid's base model: --stragglers if given,
            // else the same default cluster model as `repro scenario`.
            let scenario = match args.get("stragglers") {
                None => Scenario::parse("pareto:0.02,1.5").expect("default scenario spec parses"),
                Some(_) => stragglers_flag(args)?,
            };
            let Some(base) = scenario.latency_model().copied() else {
                return usage(
                    "--workload latparam needs a latency straggler model: \
                     --stragglers shifted-exp:BASE,RATE | pareto:SCALE,SHAPE | bimodal:FAST,SLOW,P",
                );
            };
            Workload::Latparam { base }
        }
        other => return usage(format!("unknown --workload {other:?} (fixed|latparam)")),
    };
    if matches!(workload, Workload::Fixed) && args.get("stragglers").is_some() {
        return usage("--stragglers only applies to --workload latparam");
    }
    let cfg = LoadConfig {
        addr: args.get("addr").unwrap_or("127.0.0.1:7117").to_string(),
        requests,
        concurrency,
        pipeline,
        arrival,
        seed,
        slo_p99_ms: args.f64("slo-ms", 0.0)?,
        template: DecodeRequest {
            scheme,
            k,
            n,
            s,
            r,
            rounds,
            decoder,
            // All requests share one standing assignment (drawn from
            // the root seed); the per-request field is overwritten by
            // the generator.
            assign_seed: seed,
            seed: 0,
            prefix,
        },
        workload,
    };
    let outcome = run_load(&cfg)?;
    print!("{}", outcome.replay);
    eprint!("{}", outcome.report);
    if !outcome.slo_ok {
        return Err(CliError::Runtime(anyhow::anyhow!(
            "p99 latency SLO missed (target {} ms)",
            cfg.slo_p99_ms
        )));
    }
    Ok(())
}

fn read_artifacts(paths: &[String]) -> CliResult<Vec<ShardArtifact>> {
    let mut shards = Vec::new();
    for path in paths {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let artifact = ShardArtifact::parse(&text).with_context(|| format!("parsing {path}"))?;
        shards.push(artifact);
    }
    Ok(shards)
}

fn cmd_merge(args: &Args) -> CliResult<()> {
    if args.positional.is_empty() {
        return usage("`repro merge` needs at least one shard artifact file");
    }
    let shards = read_artifacts(&args.positional)?;
    match args.get("out") {
        // Full merge: validate the complete partition and emit the CSV.
        None => {
            let merged = ShardArtifact::merge(shards)?;
            print!("{}", merged.to_csv());
        }
        // Tree-reduction step: fold the (possibly incomplete) disjoint
        // subset into one compound partial artifact.
        Some(out) => {
            let folded = ShardArtifact::merge_partial(shards)?;
            let text = folded.to_json_string();
            if out == "-" {
                print!("{text}");
            } else {
                std::fs::write(out, &text).with_context(|| format!("writing {out}"))?;
                eprintln!(
                    "folded {} artifact(s) into shards {:?} ({}/{}) of {} {} -> {out}",
                    args.positional.len(),
                    folded.shard_ids,
                    folded.shard_ids.len(),
                    folded.num_shards,
                    folded.job.kind.name(),
                    folded.job.id
                );
            }
        }
    }
    Ok(())
}

fn cmd_verify(args: &Args) -> CliResult<()> {
    if args.positional.is_empty() {
        return usage("`repro verify` needs at least one shard artifact file");
    }
    // Parsing already enforces checksum integrity per artifact.
    let shards = read_artifacts(&args.positional)?;
    ShardArtifact::verify_set(&shards)?;
    let job = &shards[0].job;
    println!(
        "OK: {} artifact(s) verify as {} {} (trials={} seed={} k={}): checksums valid, \
         shard ids 0..{} covered exactly once, every point accounts for its trial range",
        shards.len(),
        job.kind.name(),
        job.id,
        job.trials,
        job.seed,
        job.k,
        shards[0].num_shards
    );
    Ok(())
}

// ---------------------------------------------------------------- train

/// Build the requested backend. PJRT needs `make artifacts` first.
fn build_backend(args: &Args) -> CliResult<(Option<EnginePool>, Backend)> {
    let which = args.get("backend").unwrap_or("pjrt");
    match which {
        "pjrt" => {
            let manifest = Manifest::load(Manifest::default_dir())?;
            let engines = args.usize("engines", 2)?;
            let pool = EnginePool::start(manifest, engines)?;
            let backend = Backend::Pjrt(pool.handle());
            Ok((Some(pool), backend))
        }
        "native" => Ok((
            None,
            // Native dims mirror the aot.py defaults.
            Backend::Native {
                linear: LinearDims { m: 32, d: 64 },
                mlp: MlpDims { m: 32, d_in: 32, d_hidden: 64, d_out: 16, flat_dim: 3152 },
                s_max: 10,
            },
        )),
        other => usage(format!("unknown backend {other:?} (pjrt|native)")),
    }
}

fn cmd_train(args: &Args) -> CliResult<()> {
    let Some(scheme) = Scheme::parse(args.get("scheme").unwrap_or("frc")) else {
        return usage("bad --scheme (frc|bgc|rbgc|regular|cyclic)");
    };
    let model = match args.get("model").unwrap_or("linear") {
        "linear" => ModelKind::Linear,
        "mlp" => ModelKind::Mlp,
        other => return usage(format!("unknown model {other:?} (linear|mlp)")),
    };
    let k = args.usize("k", 100)?;
    let s = args.usize("s", 10)?;
    let steps = args.usize("steps", 200)?;
    let delta = args.f64("delta", 0.2)?;
    let lr = args.f64("lr", 0.5)?;

    let (_pool, backend) = build_backend(args)?;
    let mut cfg = TrainConfig::new(scheme, k, s, model);
    cfg.steps = steps;
    cfg.lr = lr;
    cfg.coordinator.seed = args.u64("seed", 0)?;
    let Some(decoder) = DecoderKind::parse(args.get("decoder").unwrap_or("onestep")) else {
        return usage("bad --decoder (onestep|optimal)");
    };
    cfg.coordinator.decoder = decoder;
    cfg.coordinator.latency = LatencyModel::Pareto { scale: 0.02, shape: 1.5 };
    let r = (((1.0 - delta) * k as f64).round() as usize).clamp(1, k);
    cfg.coordinator.deadline = DeadlinePolicy::FastestR(r);

    eprintln!(
        "training {} model, scheme={} k={k} s={s} r={r} decoder={} backend={}",
        match model {
            ModelKind::Linear => "linear",
            ModelKind::Mlp => "mlp",
        },
        scheme.name(),
        cfg.coordinator.decoder.name(),
        backend.name()
    );
    let out = train(&backend, &cfg)?;
    print!("{}", out.history.to_csv());
    eprintln!(
        "final loss {:.6e}, mean decode err {:.3e}, total gather {:.2}s",
        out.history.final_loss(),
        out.history.mean_decode_err(),
        out.history.total_gather_time()
    );
    Ok(())
}

// ------------------------------------------------------------ adversary

fn cmd_adversary(args: &Args) -> CliResult<()> {
    let k = args.usize("k", 100)?;
    let s = args.usize("s", 10)?;
    let r = args.usize("r", (k * 4) / 5)?;
    let seed = args.u64("seed", 2017)?;
    let rho = k as f64 / (r as f64 * s as f64);
    let mut rng = Rng::new(seed);

    println!("scheme,strategy,objective,err_optimal");
    for scheme in [Scheme::Frc, Scheme::Bgc, Scheme::Rbgc, Scheme::RegularGraph, Scheme::Cyclic] {
        let g = scheme.build(k, k, s).assignment(&mut rng);
        let report = |strategy: &str, ns: &[usize]| {
            let obj = asp_objective(&g, ns, rho);
            let err = OptimalDecoder::new().err(&g.select_columns(ns));
            println!("{},{strategy},{obj:.6e},{err:.6e}", scheme.name());
        };
        report("random", &rng.sample_indices(k, r));
        report("frc-block-attack", &frc_worst_stragglers(&g, r));
        report("greedy", &greedy_stragglers(&g, r, rho));
        report("local-search", &local_search_stragglers(&g, r, rho, 5));
    }
    Ok(())
}

// -------------------------------------------------------------- inspect

fn cmd_inspect(args: &Args) -> CliResult<()> {
    let manifest = Manifest::load(Manifest::default_dir())?;
    let names: Vec<String> = match args.get("artifact") {
        Some(n) => vec![n.to_string()],
        None => manifest.artifacts.iter().map(|a| a.name.clone()).collect(),
    };
    for name in names {
        let spec = manifest.spec(&name)?;
        let stats = gradcode::runtime::inspect_file(&spec.path)?;
        println!(
            "{name}: module={} computations={} instructions={} entry-params={}",
            stats.module_name, stats.computations, stats.instructions, stats.parameters
        );
        let mut ops: Vec<(&String, &usize)> = stats.opcodes.iter().collect();
        ops.sort_by_key(|&(_, c)| std::cmp::Reverse(*c));
        for (op, count) in ops.iter().take(10) {
            println!("    {op:<24} {count}");
        }
    }
    Ok(())
}

// ----------------------------------------------------------------- demo

fn cmd_demo() -> CliResult<()> {
    println!("== 1. decoding error at one figure point (k=100, s=5, delta=0.3) ==");
    let mc = MonteCarlo::new(300, 1);
    let cfg = FigureConfig { k: 100, s_values: vec![5], deltas: vec![0.3], mc };
    for p in figures::figure2(&cfg) {
        println!("  one-step {}: err1/k = {:.4}", p.scheme, p.value);
    }
    for p in figures::figure3(&cfg) {
        println!("  optimal  {}: err/k  = {:.4}", p.scheme, p.value);
    }

    println!("== 2. the Thm-10 attack on FRC (k=100, s=10, r=80) ==");
    let mut rng = Rng::new(2);
    let g = Scheme::Frc.build(100, 100, 10).assignment(&mut rng);
    let ns = frc_worst_stragglers(&g, 80);
    let err = OptimalDecoder::new().err(&g.select_columns(&ns));
    println!("  adversarial err = {err} (theory: k - r = 20)");

    println!("== 3. coded training, native backend (k=20, s=5, 25% stragglers) ==");
    let backend = Backend::Native {
        linear: LinearDims { m: 16, d: 16 },
        mlp: MlpDims { m: 8, d_in: 8, d_hidden: 16, d_out: 4, flat_dim: 8 * 16 + 16 + 16 * 4 + 4 },
        s_max: 10,
    };
    let mut cfg = TrainConfig::new(Scheme::Frc, 20, 5, ModelKind::Linear);
    cfg.steps = 30;
    cfg.coordinator.deadline = DeadlinePolicy::FastestR(15);
    let out = train(&backend, &cfg)?;
    println!(
        "  loss {:.4} -> {:.4} over {} rounds with 5/20 stragglers per round",
        out.history.rounds[0].loss,
        out.history.final_loss(),
        out.history.rounds.len()
    );
    println!("demo OK");
    Ok(())
}
