//! # gradcode — Approximate Gradient Coding via Sparse Random Graphs
//!
//! A production-quality reproduction of Charles, Papailiopoulos &
//! Ellenberg (2017) as a three-layer Rust + JAX + Pallas system. See
//! the repository's README.md for an overview and ARCHITECTURE.md for
//! the decode-pipeline and sharding design.
//!
//! * [`codes`] — FRC / BGC / rBGC / s-regular / cyclic constructions.
//! * [`decode`] — one-step, optimal (LSQR), and algorithmic decoders.
//! * [`stragglers`] — the straggler-scenario spine: uniform, latency-
//!   deadline, and adversarial models behind one pluggable trait, plus
//!   the CLI-facing [`stragglers::Scenario`] run identity.
//! * [`adversary`] — Thm-10 FRC attack, greedy/local-search/exhaustive
//!   heuristics, and the Thm-11 DkS reduction.
//! * [`sim`] — Monte-Carlo harness regenerating Figures 2-5 and the
//!   theorem tables; [`sim::shard`] fans any run out across
//!   processes/machines with bit-identical merged results.
//! * [`runtime`] — PJRT engine pool executing the AOT HLO artifacts.
//! * [`coordinator`] — master/worker gather, deadline, decode.
//! * [`training`] — synthetic data + the end-to-end coded GD loop.
//! * [`serve`] — the `repro serve` daemon: length-prefixed JSON
//!   frames, hot per-connection decode workspaces, memoized standing
//!   assignments, a `/metrics` endpoint, and the fan-out job scheduler
//!   (shared with `repro run --fanout`).
//! * [`load`] — seeded deterministic traffic generator with
//!   byte-reproducible replays and latency/throughput SLO reports.
//! * [`graph`], [`linalg`], [`util`] — substrates built from scratch.

pub mod adversary;
pub mod codes;
pub mod coordinator;
pub mod decode;
pub mod graph;
pub mod linalg;
pub mod load;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod stragglers;
pub mod training;
pub mod util;

// Compile the README / ARCHITECTURE code blocks as doctests so the
// documented examples cannot rot (CI runs `cargo test --doc`). The
// structs exist only under rustdoc's doctest collection pass.
#[doc = include_str!("../../README.md")]
#[cfg(doctest)]
pub struct ReadmeDoctests;

#[doc = include_str!("../../ARCHITECTURE.md")]
#[cfg(doctest)]
pub struct ArchitectureDoctests;
