//! API-compatible stand-in for the PJRT engine pool, compiled when the
//! `pjrt` cargo feature is off (the `xla` bindings are not in the
//! offline vendor set).
//!
//! Every type and method signature matches `engine.rs`, so callers —
//! the CLI, the coordinator, tests, benches — compile unchanged and get
//! a clear runtime error directing them to the native backend (or to a
//! build with `--features pjrt`). The pjrt integration tests skip
//! before ever constructing a pool (they bail when artifacts are
//! missing), so the default test suite never hits these errors.

use std::sync::Arc;

use anyhow::{bail, Result};

use super::artifact::Manifest;

/// Clonable submission handle (stub: carries only the manifest).
#[derive(Clone)]
pub struct EngineHandle {
    manifest: Arc<Manifest>,
}

/// Stub pool: construction always fails with a build-configuration hint.
pub struct EnginePool {
    handle: EngineHandle,
    workers: usize,
}

impl EnginePool {
    /// Always fails: the real engine needs the `pjrt` feature.
    pub fn start(manifest: Manifest, workers: usize) -> Result<EnginePool> {
        let _ = (manifest, workers);
        bail!(
            "gradcode was built without the `pjrt` feature (the xla \
             bindings are not in the offline vendor set); rebuild with \
             `--features pjrt` or use the native backend"
        )
    }

    pub fn handle(&self) -> EngineHandle {
        self.handle.clone()
    }

    pub fn workers(&self) -> usize {
        self.workers
    }
}

impl EngineHandle {
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Always fails (see [`EnginePool::start`]).
    pub fn run(&self, artifact: &str, inputs: Vec<Vec<f32>>) -> Result<Vec<Vec<f32>>> {
        let _ = (artifact, inputs);
        bail!("PJRT engine unavailable: gradcode was built without the `pjrt` feature")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn start_reports_missing_feature() {
        use super::super::artifact::{LinearDims, MlpDims};
        let manifest = Manifest {
            dir: std::path::PathBuf::from("artifacts"),
            s_max: 1,
            linear: LinearDims { m: 1, d: 1 },
            mlp: MlpDims { m: 1, d_in: 1, d_hidden: 1, d_out: 1, flat_dim: 5 },
            artifacts: Vec::new(),
        };
        let err = match EnginePool::start(manifest, 2) {
            Err(e) => format!("{e}"),
            Ok(_) => panic!("stub pool must not start"),
        };
        assert!(err.contains("pjrt"), "{err}");
    }
}
