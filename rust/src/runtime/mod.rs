//! Runtime layer: load and execute the AOT artifacts from the hot path.
//!
//! * [`artifact`] — manifest parsing (the aot.py ⇄ Rust contract).
//! * [`engine`]   — PJRT engine pool (per-thread CPU clients; HLO text →
//!   compile → execute).
//! * [`native`]   — pure-Rust reference backend (test oracle + fallback).
//!
//! [`Backend`] abstracts the two so the coordinator is agnostic.

pub mod artifact;

// The real PJRT engine needs the external `xla` bindings, which the
// offline vendor set does not ship. Enabling `pjrt` without them would
// die mid-compile on unresolved `xla::` paths, so fail fast with an
// actionable message instead; builds that have added the dependency
// opt in with `RUSTFLAGS="--cfg gradcode_has_xla"`.
#[cfg(all(feature = "pjrt", not(gradcode_has_xla)))]
compile_error!(
    "the `pjrt` feature requires the external `xla` bindings: add `xla` \
     to [dependencies] in rust/Cargo.toml and build with \
     RUSTFLAGS=\"--cfg gradcode_has_xla\" (see the Cargo.toml header)"
);
#[cfg(all(feature = "pjrt", gradcode_has_xla))]
pub mod engine;
#[cfg(not(all(feature = "pjrt", gradcode_has_xla)))]
#[path = "engine_stub.rs"]
pub mod engine;
pub mod hlo_inspect;
pub mod native;

pub use artifact::{ArtifactSpec, LinearDims, Manifest, MlpDims};
pub use engine::{EngineHandle, EnginePool};
pub use hlo_inspect::{inspect_file, parse_hlo_text, HloStats};

use anyhow::Result;

/// Gradient-compute backend used by workers.
#[derive(Clone)]
pub enum Backend {
    /// AOT HLO artifacts executed on the PJRT engine pool.
    Pjrt(EngineHandle),
    /// Pure-Rust reference implementation (same math, no artifacts).
    Native { linear: LinearDims, mlp: MlpDims, s_max: usize },
}

impl Backend {
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Pjrt(_) => "pjrt",
            Backend::Native { .. } => "native",
        }
    }

    pub fn linear_dims(&self) -> LinearDims {
        match self {
            Backend::Pjrt(h) => h.manifest().linear,
            Backend::Native { linear, .. } => *linear,
        }
    }

    pub fn mlp_dims(&self) -> MlpDims {
        match self {
            Backend::Pjrt(h) => h.manifest().mlp,
            Backend::Native { mlp, .. } => *mlp,
        }
    }

    pub fn s_max(&self) -> usize {
        match self {
            Backend::Pjrt(h) => h.manifest().s_max,
            Backend::Native { s_max, .. } => *s_max,
        }
    }

    /// Partition gradient of the linear model.
    pub fn linear_grad(&self, x: &[f32], w: &[f32], y: &[f32]) -> Result<Vec<f32>> {
        match self {
            Backend::Pjrt(h) => {
                let mut out = h.run("grad_linear", vec![x.to_vec(), w.to_vec(), y.to_vec()])?;
                Ok(out.remove(0))
            }
            Backend::Native { linear, .. } => native::linear_grad(*linear, x, w, y),
        }
    }

    /// Partition (loss, gradient) of the MLP.
    pub fn mlp_grad(&self, theta: &[f32], x: &[f32], y: &[f32]) -> Result<(f32, Vec<f32>)> {
        match self {
            Backend::Pjrt(h) => {
                let mut out =
                    h.run("grad_mlp", vec![theta.to_vec(), x.to_vec(), y.to_vec()])?;
                let loss = out.remove(0);
                let grad = out.remove(0);
                Ok((loss[0], grad))
            }
            Backend::Native { mlp, .. } => native::mlp_grad(*mlp, theta, x, y),
        }
    }

    /// True when the fused one-dispatch worker-message modules are
    /// available (msg_linear / msg_mlp artifacts, or native backend).
    pub fn has_fused_message(&self) -> bool {
        match self {
            Backend::Pjrt(h) => {
                h.manifest().spec("msg_linear").is_ok() && h.manifest().spec("msg_mlp").is_ok()
            }
            Backend::Native { .. } => true,
        }
    }

    /// Fused linear worker round: s_max partition gradients + coded
    /// combine in ONE dispatch (xs (s,m,d), ys (s,m), coeffs (s)).
    pub fn linear_message(
        &self,
        w: &[f32],
        xs: &[f32],
        ys: &[f32],
        coeffs: &[f32],
    ) -> Result<Vec<f32>> {
        match self {
            Backend::Pjrt(h) => {
                let mut out = h.run(
                    "msg_linear",
                    vec![w.to_vec(), xs.to_vec(), ys.to_vec(), coeffs.to_vec()],
                )?;
                Ok(out.remove(0))
            }
            Backend::Native { linear, s_max, .. } => {
                native::linear_message(*linear, *s_max, w, xs, ys, coeffs)
            }
        }
    }

    /// Fused MLP worker round: (losses (s,), message (flat_dim,)).
    pub fn mlp_message(
        &self,
        theta: &[f32],
        xs: &[f32],
        ys: &[f32],
        coeffs: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        match self {
            Backend::Pjrt(h) => {
                let mut out = h.run(
                    "msg_mlp",
                    vec![theta.to_vec(), xs.to_vec(), ys.to_vec(), coeffs.to_vec()],
                )?;
                let losses = out.remove(0);
                let msg = out.remove(0);
                Ok((losses, msg))
            }
            Backend::Native { mlp, s_max, .. } => {
                native::mlp_message(*mlp, *s_max, theta, xs, ys, coeffs)
            }
        }
    }

    /// Coded worker message: coeffs @ grads for (s_max, d) stacked grads.
    /// `which` picks the matching combine artifact dimension.
    pub fn combine(&self, which: CombineKind, grads: &[f32], coeffs: &[f32]) -> Result<Vec<f32>> {
        let d = match which {
            CombineKind::Linear => self.linear_dims().d,
            CombineKind::Mlp => self.mlp_dims().flat_dim,
        };
        let s = self.s_max();
        match self {
            Backend::Pjrt(h) => {
                let name = match which {
                    CombineKind::Linear => "combine_linear",
                    CombineKind::Mlp => "combine_mlp",
                };
                let mut out = h.run(name, vec![grads.to_vec(), coeffs.to_vec()])?;
                Ok(out.remove(0))
            }
            Backend::Native { .. } => native::coded_combine(s, d, grads, coeffs),
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CombineKind {
    Linear,
    Mlp,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn native_backend() -> Backend {
        Backend::Native {
            linear: LinearDims { m: 4, d: 3 },
            mlp: MlpDims { m: 4, d_in: 3, d_hidden: 4, d_out: 2, flat_dim: 3 * 4 + 4 + 4 * 2 + 2 },
            s_max: 3,
        }
    }

    #[test]
    fn native_backend_roundtrip() {
        let b = native_backend();
        let x = vec![1.0f32; 12];
        let w = vec![0.5f32; 3];
        let y = vec![1.0f32; 4];
        let g = b.linear_grad(&x, &w, &y).unwrap();
        assert_eq!(g.len(), 3);
        // Xw = 1.5 per row, residual 0.5, g = mean over rows of x*0.5 = 0.5
        for v in g {
            assert!((v - 0.5).abs() < 1e-6);
        }
    }

    #[test]
    fn combine_uses_s_max_rows() {
        let b = native_backend();
        let d = 3;
        let grads = vec![1.0f32; 3 * d];
        let msg = b.combine(CombineKind::Linear, &grads, &[1.0, 1.0, 0.0]).unwrap();
        assert_eq!(msg, vec![2.0, 2.0, 2.0]);
    }
}
