//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime. Parses `artifacts/manifest.json` (shapes + files)
//! so the engine can validate inputs before handing them to PJRT.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::Json;

/// Static shapes of the linear partition gradient.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LinearDims {
    pub m: usize,
    pub d: usize,
}

/// Static shapes of the MLP partition gradient.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MlpDims {
    pub m: usize,
    pub d_in: usize,
    pub d_hidden: usize,
    pub d_out: usize,
    pub flat_dim: usize,
}

/// One AOT-compiled entry point.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub path: PathBuf,
    /// Input shapes in argument order (row-major dims).
    pub inputs: Vec<Vec<usize>>,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub s_max: usize,
    pub linear: LinearDims,
    pub mlp: MlpDims,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts` first)"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        if j.get("format")?.as_str()? != "hlo-text" {
            bail!("unsupported artifact format (expected hlo-text)");
        }

        let lin = j.get("linear")?;
        let linear = LinearDims {
            m: lin.get("m")?.as_usize()?,
            d: lin.get("d")?.as_usize()?,
        };
        let mj = j.get("mlp")?;
        let mlp = MlpDims {
            m: mj.get("m")?.as_usize()?,
            d_in: mj.get("d_in")?.as_usize()?,
            d_hidden: mj.get("d_hidden")?.as_usize()?,
            d_out: mj.get("d_out")?.as_usize()?,
            flat_dim: mj.get("flat_dim")?.as_usize()?,
        };
        let expected_flat =
            mlp.d_in * mlp.d_hidden + mlp.d_hidden + mlp.d_hidden * mlp.d_out + mlp.d_out;
        if expected_flat != mlp.flat_dim {
            bail!("manifest flat_dim {} != derived {}", mlp.flat_dim, expected_flat);
        }

        let mut artifacts = Vec::new();
        for (name, spec) in j.get("artifacts")?.as_obj()? {
            let file = spec.get("file")?.as_str()?;
            let inputs = spec
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(|shape| {
                    shape
                        .as_arr()?
                        .iter()
                        .map(|d| d.as_usize())
                        .collect::<Result<Vec<usize>>>()
                })
                .collect::<Result<Vec<_>>>()?;
            let path = dir.join(file);
            if !path.exists() {
                bail!("artifact file missing: {path:?}");
            }
            artifacts.push(ArtifactSpec { name: name.clone(), path, inputs });
        }

        Ok(Manifest {
            dir,
            s_max: j.get("s_max")?.as_usize()?,
            linear,
            mlp,
            artifacts,
        })
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .with_context(|| format!("artifact {name:?} not in manifest"))
    }

    /// Default artifact directory: $GRADCODE_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("GRADCODE_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }
}

/// Number of elements implied by a shape.
pub fn shape_len(shape: &[usize]) -> usize {
    shape.iter().product()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        let mut f = std::fs::File::create(dir.join("manifest.json")).unwrap();
        f.write_all(body.as_bytes()).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("gradcode-manifest-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    const BODY: &str = r#"{
      "format": "hlo-text", "dtype": "f32", "s_max": 4,
      "linear": {"m": 8, "d": 16},
      "mlp": {"m": 8, "d_in": 8, "d_hidden": 16, "d_out": 4, "flat_dim": 212},
      "artifacts": {
        "grad_linear": {"file": "grad_linear.hlo.txt", "inputs": [[8,16],[16],[8]]}
      }
    }"#;

    #[test]
    fn loads_valid_manifest() {
        let dir = tmpdir("ok");
        write_manifest(&dir, BODY);
        std::fs::write(dir.join("grad_linear.hlo.txt"), "HloModule m").unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.s_max, 4);
        assert_eq!(m.linear, LinearDims { m: 8, d: 16 });
        assert_eq!(m.mlp.flat_dim, 212);
        let spec = m.spec("grad_linear").unwrap();
        assert_eq!(spec.inputs, vec![vec![8, 16], vec![16], vec![8]]);
        assert!(m.spec("nope").is_err());
    }

    #[test]
    fn rejects_missing_artifact_file() {
        let dir = tmpdir("missing");
        write_manifest(&dir, BODY);
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn rejects_inconsistent_flat_dim() {
        let dir = tmpdir("flat");
        write_manifest(&dir, &BODY.replace("212", "999"));
        std::fs::write(dir.join("grad_linear.hlo.txt"), "HloModule m").unwrap();
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn shape_len_products() {
        assert_eq!(shape_len(&[8, 16]), 128);
        assert_eq!(shape_len(&[]), 1);
    }
}
