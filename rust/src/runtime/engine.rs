//! PJRT execution engine pool.
//!
//! The `xla` crate's PjRtClient is Rc-based (not Send), so each engine
//! runs on its own OS thread with its own CPU client and its own compiled
//! copies of every artifact. Callers hold a cheap, clonable `EngineHandle`
//! and submit `(artifact name, input buffers)`; requests are distributed
//! over the pool via a shared work queue. Python never runs here — the
//! engines load the HLO text that `make artifacts` produced.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Context, Result};

use super::artifact::{shape_len, Manifest};

/// A request: run `artifact` on `inputs` (row-major f32 buffers).
struct Request {
    artifact: String,
    inputs: Vec<Vec<f32>>,
    reply: Sender<Result<Vec<Vec<f32>>>>,
}

enum Job {
    Run(Request),
    Shutdown,
}

/// Clonable submission handle to the engine pool.
///
/// The queue sender sits behind a mutex so the handle is `Send + Sync`
/// (std's mpsc `Sender` is not `Sync`); the lock is held only for the
/// enqueue, never during execution.
#[derive(Clone)]
pub struct EngineHandle {
    queue: Arc<Mutex<Sender<Job>>>,
    manifest: Arc<Manifest>,
}

/// The pool itself; dropping it shuts the engine threads down.
pub struct EnginePool {
    handle: EngineHandle,
    threads: Vec<JoinHandle<()>>,
    shutdown_tx: Sender<Job>,
    workers: usize,
}

impl EnginePool {
    /// Spawn `workers` engine threads, each compiling all artifacts.
    pub fn start(manifest: Manifest, workers: usize) -> Result<EnginePool> {
        let workers = workers.max(1);
        let manifest = Arc::new(manifest);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));

        let mut threads = Vec::with_capacity(workers);
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        for i in 0..workers {
            let rx = Arc::clone(&rx);
            let manifest = Arc::clone(&manifest);
            let ready = ready_tx.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("pjrt-engine-{i}"))
                    .spawn(move || engine_thread(manifest, rx, ready))
                    .context("spawning engine thread")?,
            );
        }
        drop(ready_tx);
        // Wait for every engine to finish compiling (or fail fast).
        for _ in 0..workers {
            ready_rx
                .recv()
                .map_err(|_| anyhow!("engine thread died during startup"))??;
        }
        let handle = EngineHandle { queue: Arc::new(Mutex::new(tx.clone())), manifest };
        Ok(EnginePool { handle, threads, shutdown_tx: tx, workers })
    }

    pub fn handle(&self) -> EngineHandle {
        self.handle.clone()
    }

    pub fn workers(&self) -> usize {
        self.workers
    }
}

impl Drop for EnginePool {
    fn drop(&mut self) {
        for _ in 0..self.threads.len() {
            let _ = self.shutdown_tx.send(Job::Shutdown);
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl EngineHandle {
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Validate shapes and execute `artifact` on the pool (blocking).
    /// Returns the tuple outputs as row-major f32 buffers.
    pub fn run(&self, artifact: &str, inputs: Vec<Vec<f32>>) -> Result<Vec<Vec<f32>>> {
        let spec = self.manifest.spec(artifact)?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "{artifact}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (buf, shape)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if buf.len() != shape_len(shape) {
                bail!(
                    "{artifact}: input {i} has {} elements, shape {:?} needs {}",
                    buf.len(),
                    shape,
                    shape_len(shape)
                );
            }
        }
        let (reply_tx, reply_rx) = channel();
        self.queue
            .lock()
            .unwrap()
            .send(Job::Run(Request {
                artifact: artifact.to_string(),
                inputs,
                reply: reply_tx,
            }))
            .map_err(|_| anyhow!("engine pool is shut down"))?;
        reply_rx.recv().map_err(|_| anyhow!("engine dropped the request"))?
    }
}

/// Body of one engine thread: build client, compile artifacts, serve.
fn engine_thread(
    manifest: Arc<Manifest>,
    rx: Arc<Mutex<Receiver<Job>>>,
    ready: Sender<Result<()>>,
) {
    let setup = || -> Result<(xla::PjRtClient, HashMap<String, xla::PjRtLoadedExecutable>)> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut exes = HashMap::new();
        for spec in &manifest.artifacts {
            let proto = xla::HloModuleProto::from_text_file(&spec.path)
                .with_context(|| format!("parsing HLO text {:?}", spec.path))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {:?}", spec.name))?;
            exes.insert(spec.name.clone(), exe);
        }
        Ok((client, exes))
    };

    let (_client, exes) = match setup() {
        Ok(v) => {
            let _ = ready.send(Ok(()));
            v
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    loop {
        let job = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        match job {
            Ok(Job::Run(req)) => {
                let result = execute(&exes, &manifest, &req);
                let _ = req.reply.send(result);
            }
            Ok(Job::Shutdown) | Err(_) => return,
        }
    }
}

fn execute(
    exes: &HashMap<String, xla::PjRtLoadedExecutable>,
    manifest: &Manifest,
    req: &Request,
) -> Result<Vec<Vec<f32>>> {
    let exe = exes
        .get(&req.artifact)
        .ok_or_else(|| anyhow!("artifact {:?} not compiled", req.artifact))?;
    let spec = manifest.spec(&req.artifact)?;

    // Build literals with the manifest shapes.
    let mut literals = Vec::with_capacity(req.inputs.len());
    for (buf, shape) in req.inputs.iter().zip(&spec.inputs) {
        let lit = xla::Literal::vec1(buf);
        let lit = if shape.len() == 1 {
            lit
        } else {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            lit.reshape(&dims).context("reshaping input literal")?
        };
        literals.push(lit);
    }

    let result = exe
        .execute::<xla::Literal>(&literals)
        .with_context(|| format!("executing {:?}", req.artifact))?;
    let tuple = result[0][0]
        .to_literal_sync()
        .context("fetching result literal")?;
    // aot.py lowers with return_tuple=True: unwrap the tuple.
    let parts = tuple.to_tuple().context("untupling result")?;
    parts
        .into_iter()
        .map(|lit| lit.to_vec::<f32>().context("reading f32 output"))
        .collect()
}
