//! Native Rust reference backend — the same math as the AOT artifacts,
//! written directly in Rust.
//!
//! Three jobs: (1) test oracle for the PJRT path (integration tests
//! assert PJRT == native to f32 tolerance); (2) artifact-free fallback
//! so the simulation/figure stack runs even before `make artifacts`;
//! (3) baseline for the runtime benchmarks (PJRT dispatch overhead vs
//! plain loops).

use anyhow::{bail, Result};

use super::artifact::{LinearDims, MlpDims};

/// g = X^T (X w - y) / m  (matches kernels/linear_grad.py).
pub fn linear_grad(dims: LinearDims, x: &[f32], w: &[f32], y: &[f32]) -> Result<Vec<f32>> {
    let (m, d) = (dims.m, dims.d);
    if x.len() != m * d || w.len() != d || y.len() != m {
        bail!("linear_grad shape mismatch");
    }
    let mut g = vec![0.0f32; d];
    for i in 0..m {
        let row = &x[i * d..(i + 1) * d];
        let mut r = -y[i];
        for (xv, wv) in row.iter().zip(w) {
            r += xv * wv;
        }
        for (gj, xv) in g.iter_mut().zip(row) {
            *gj += xv * r;
        }
    }
    let inv_m = 1.0 / m as f32;
    for gj in g.iter_mut() {
        *gj *= inv_m;
    }
    Ok(g)
}

/// (loss, flat_grad) of the 2-layer tanh MLP with MSE loss
/// (matches model.mlp_partition_grad).
pub fn mlp_grad(dims: MlpDims, theta: &[f32], x: &[f32], y: &[f32]) -> Result<(f32, Vec<f32>)> {
    let MlpDims { m, d_in, d_hidden, d_out, flat_dim } = dims;
    if theta.len() != flat_dim || x.len() != m * d_in || y.len() != m * d_out {
        bail!("mlp_grad shape mismatch");
    }
    let (w1, rest) = theta.split_at(d_in * d_hidden);
    let (b1, rest) = rest.split_at(d_hidden);
    let (w2, b2) = rest.split_at(d_hidden * d_out);

    // Forward.
    let mut h = vec![0.0f32; m * d_hidden]; // tanh(z1)
    for i in 0..m {
        for j in 0..d_hidden {
            let mut z = b1[j];
            for t in 0..d_in {
                z += x[i * d_in + t] * w1[t * d_hidden + j];
            }
            h[i * d_hidden + j] = z.tanh();
        }
    }
    let mut diff = vec![0.0f32; m * d_out]; // o - y
    let mut loss = 0.0f32;
    for i in 0..m {
        for j in 0..d_out {
            let mut o = b2[j];
            for t in 0..d_hidden {
                o += h[i * d_hidden + t] * w2[t * d_out + j];
            }
            let dv = o - y[i * d_out + j];
            diff[i * d_out + j] = dv;
            loss += dv * dv;
        }
    }
    loss /= (m * d_out) as f32;

    // Backward: dO = 2 (O - Y) / (m * d_out).
    let scale = 2.0 / (m * d_out) as f32;
    let do_: Vec<f32> = diff.iter().map(|v| v * scale).collect();

    let mut dw2 = vec![0.0f32; d_hidden * d_out];
    let mut db2 = vec![0.0f32; d_out];
    for i in 0..m {
        for j in 0..d_out {
            let g = do_[i * d_out + j];
            db2[j] += g;
            for t in 0..d_hidden {
                dw2[t * d_out + j] += h[i * d_hidden + t] * g;
            }
        }
    }
    // dH = dO W2^T; dZ1 = dH * (1 - h^2)
    let mut dz1 = vec![0.0f32; m * d_hidden];
    for i in 0..m {
        for t in 0..d_hidden {
            let mut dh = 0.0f32;
            for j in 0..d_out {
                dh += do_[i * d_out + j] * w2[t * d_out + j];
            }
            let hv = h[i * d_hidden + t];
            dz1[i * d_hidden + t] = dh * (1.0 - hv * hv);
        }
    }
    let mut dw1 = vec![0.0f32; d_in * d_hidden];
    let mut db1 = vec![0.0f32; d_hidden];
    for i in 0..m {
        for t in 0..d_hidden {
            let g = dz1[i * d_hidden + t];
            db1[t] += g;
            for u in 0..d_in {
                dw1[u * d_hidden + t] += x[i * d_in + u] * g;
            }
        }
    }

    let mut flat = Vec::with_capacity(flat_dim);
    flat.extend_from_slice(&dw1);
    flat.extend_from_slice(&db1);
    flat.extend_from_slice(&dw2);
    flat.extend_from_slice(&db2);
    Ok((loss, flat))
}

/// v = coeffs @ grads (matches kernels/combine.py). grads is (s, d)
/// row-major.
pub fn coded_combine(s: usize, d: usize, grads: &[f32], coeffs: &[f32]) -> Result<Vec<f32>> {
    if grads.len() != s * d || coeffs.len() != s {
        bail!("coded_combine shape mismatch");
    }
    let mut v = vec![0.0f32; d];
    for (i, &c) in coeffs.iter().enumerate() {
        if c == 0.0 {
            continue;
        }
        let row = &grads[i * d..(i + 1) * d];
        for (vj, gj) in v.iter_mut().zip(row) {
            *vj += c * gj;
        }
    }
    Ok(v)
}

/// Fused linear worker round (mirrors model.linear_worker_message):
/// s partition gradients + coded combine in one call.
pub fn linear_message(
    dims: LinearDims,
    s: usize,
    w: &[f32],
    xs: &[f32],
    ys: &[f32],
    coeffs: &[f32],
) -> Result<Vec<f32>> {
    let (m, d) = (dims.m, dims.d);
    if xs.len() != s * m * d || ys.len() != s * m || coeffs.len() != s {
        bail!("linear_message shape mismatch");
    }
    let mut grads = vec![0.0f32; s * d];
    for i in 0..s {
        let g = linear_grad(dims, &xs[i * m * d..(i + 1) * m * d], w, &ys[i * m..(i + 1) * m])?;
        grads[i * d..(i + 1) * d].copy_from_slice(&g);
    }
    coded_combine(s, d, &grads, coeffs)
}

/// Fused MLP worker round (mirrors model.mlp_worker_message):
/// returns (per-shard losses, coded message).
pub fn mlp_message(
    dims: MlpDims,
    s: usize,
    theta: &[f32],
    xs: &[f32],
    ys: &[f32],
    coeffs: &[f32],
) -> Result<(Vec<f32>, Vec<f32>)> {
    let (m, din, dout, f) = (dims.m, dims.d_in, dims.d_out, dims.flat_dim);
    if xs.len() != s * m * din || ys.len() != s * m * dout || coeffs.len() != s {
        bail!("mlp_message shape mismatch");
    }
    let mut losses = vec![0.0f32; s];
    let mut grads = vec![0.0f32; s * f];
    for i in 0..s {
        let (loss, flat) = mlp_grad(
            dims,
            theta,
            &xs[i * m * din..(i + 1) * m * din],
            &ys[i * m * dout..(i + 1) * m * dout],
        )?;
        losses[i] = loss;
        grads[i * f..(i + 1) * f].copy_from_slice(&flat);
    }
    let msg = coded_combine(s, f, &grads, coeffs)?;
    Ok((losses, msg))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randf(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32 * scale).collect()
    }

    #[test]
    fn linear_grad_zero_at_solution() {
        let dims = LinearDims { m: 8, d: 4 };
        let mut rng = Rng::new(1);
        let x = randf(&mut rng, 32, 1.0);
        let w = randf(&mut rng, 4, 1.0);
        // y = X w exactly.
        let mut y = vec![0.0f32; 8];
        for i in 0..8 {
            for j in 0..4 {
                y[i] += x[i * 4 + j] * w[j];
            }
        }
        let g = linear_grad(dims, &x, &w, &y).unwrap();
        assert!(g.iter().all(|v| v.abs() < 1e-5), "{g:?}");
    }

    #[test]
    fn linear_grad_matches_finite_difference() {
        let dims = LinearDims { m: 6, d: 3 };
        let mut rng = Rng::new(2);
        let x = randf(&mut rng, 18, 1.0);
        let w = randf(&mut rng, 3, 1.0);
        let y = randf(&mut rng, 6, 1.0);
        let g = linear_grad(dims, &x, &w, &y).unwrap();
        // loss = ||Xw - y||^2 / (2m); grad = X^T(Xw-y)/m.
        let loss = |w: &[f32]| -> f64 {
            let mut acc = 0.0f64;
            for i in 0..6 {
                let mut r = -y[i] as f64;
                for j in 0..3 {
                    r += (x[i * 3 + j] * w[j]) as f64;
                }
                acc += r * r;
            }
            acc / 12.0
        };
        let eps = 1e-3f32;
        for j in 0..3 {
            let mut wp = w.clone();
            wp[j] += eps;
            let mut wm = w.clone();
            wm[j] -= eps;
            let fd = (loss(&wp) - loss(&wm)) / (2.0 * eps as f64);
            assert!((fd - g[j] as f64).abs() < 1e-3, "j={j}: fd {fd} vs {}", g[j]);
        }
    }

    #[test]
    fn mlp_grad_matches_finite_difference() {
        let dims = MlpDims { m: 4, d_in: 3, d_hidden: 5, d_out: 2, flat_dim: 3 * 5 + 5 + 5 * 2 + 2 };
        let mut rng = Rng::new(3);
        let theta = randf(&mut rng, dims.flat_dim, 0.3);
        let x = randf(&mut rng, 12, 1.0);
        let y = randf(&mut rng, 8, 1.0);
        let (loss0, flat) = mlp_grad(dims, &theta, &x, &y).unwrap();
        assert!(loss0 > 0.0);
        let eps = 1e-2f32;
        // Spot-check a few coordinates across all parameter groups.
        for &j in &[0usize, 7, 15, 16, 20, 25, 30, dims.flat_dim - 1] {
            let mut tp = theta.clone();
            tp[j] += eps;
            let mut tm = theta.clone();
            tm[j] -= eps;
            let (lp, _) = mlp_grad(dims, &tp, &x, &y).unwrap();
            let (lm, _) = mlp_grad(dims, &tm, &x, &y).unwrap();
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - flat[j]).abs() < 2e-3 * (1.0 + flat[j].abs()),
                "coord {j}: fd {fd} vs analytic {}",
                flat[j]
            );
        }
    }

    #[test]
    fn mlp_descends() {
        let dims = MlpDims { m: 8, d_in: 4, d_hidden: 8, d_out: 2, flat_dim: 4 * 8 + 8 + 8 * 2 + 2 };
        let mut rng = Rng::new(4);
        let mut theta = randf(&mut rng, dims.flat_dim, 0.3);
        let x = randf(&mut rng, 32, 1.0);
        let y = randf(&mut rng, 16, 1.0);
        let (l0, mut g) = mlp_grad(dims, &theta, &x, &y).unwrap();
        let mut l = l0;
        for _ in 0..30 {
            for (t, gv) in theta.iter_mut().zip(&g) {
                *t -= 0.5 * gv;
            }
            let (ln, gn) = mlp_grad(dims, &theta, &x, &y).unwrap();
            l = ln;
            g = gn;
        }
        assert!(l < l0, "loss {l0} -> {l}");
    }

    #[test]
    fn combine_selects_and_sums() {
        let grads = vec![1.0, 2.0, 10.0, 20.0, 100.0, 200.0];
        let v = coded_combine(3, 2, &grads, &[1.0, 0.0, 1.0]).unwrap();
        assert_eq!(v, vec![101.0, 202.0]);
        let v = coded_combine(3, 2, &grads, &[0.5, 1.0, 0.0]).unwrap();
        assert_eq!(v, vec![10.5, 21.0]);
    }

    #[test]
    fn shape_mismatch_errors() {
        assert!(linear_grad(LinearDims { m: 2, d: 2 }, &[0.0; 3], &[0.0; 2], &[0.0; 2]).is_err());
        assert!(coded_combine(2, 2, &[0.0; 4], &[0.0; 3]).is_err());
    }
}
