//! HLO-text inspector: L2 profiling without loading Python.
//!
//! Parses the AOT artifacts' HLO text into summary statistics —
//! instruction counts by opcode, computation count, parameter/root
//! shapes — used by the §Perf L2 analysis ("no redundant recomputation,
//! fused where XLA can fuse") and by tests that assert the lowered
//! graphs have the expected structure (e.g. grad_mlp contains the five
//! dots of the hand-written backward pass, not more).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

/// Summary of one HLO module's text.
#[derive(Clone, Debug, Default)]
pub struct HloStats {
    pub module_name: String,
    pub computations: usize,
    pub instructions: usize,
    /// instruction count per opcode (dot, add, tanh, ...).
    pub opcodes: BTreeMap<String, usize>,
    /// Parameter count of the ENTRY computation only (the module's
    /// actual inputs; nested fusion computations have their own).
    pub parameters: usize,
}

impl HloStats {
    pub fn count(&self, opcode: &str) -> usize {
        self.opcodes.get(opcode).copied().unwrap_or(0)
    }
}

/// Parse HLO text into stats. The text grammar is
/// `result = opcode(...)` per instruction line; computations open with
/// `{` after a signature line (`ENTRY ... {` or `%name ... {`).
pub fn parse_hlo_text(text: &str) -> HloStats {
    let mut stats = HloStats::default();
    let mut in_entry = false;
    for line in text.lines() {
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with("//") {
            continue;
        }
        if let Some(rest) = trimmed.strip_prefix("HloModule") {
            stats.module_name =
                rest.trim().split([',', ' ']).next().unwrap_or("").to_string();
            continue;
        }
        if trimmed.ends_with('{') {
            stats.computations += 1;
            in_entry = trimmed.starts_with("ENTRY");
            continue;
        }
        if trimmed == "}" {
            in_entry = false;
            continue;
        }
        // Instruction lines: `[ROOT] %name = type opcode(args)`.
        let body = trimmed.strip_prefix("ROOT ").unwrap_or(trimmed);
        let Some(eq) = body.find(" = ") else { continue };
        let rhs = &body[eq + 3..];
        // rhs looks like `f32[2,2]{1,0} dot(%a, %b), contracting...` or
        // `(f32[2]{0}, s32[]) tuple(...)` — skip type tokens (anything
        // with brackets / trailing commas / leading parens) until the
        // opcode token.
        let looks_like_type = |t: &str| {
            t.starts_with('(')
                || t.ends_with(',')
                || t.contains('[')
                || t.contains('{')
                || t.ends_with(')')
        };
        let mut tokens = rhs.split_whitespace();
        let mut opcode_token = match tokens.next() {
            Some(t) => t,
            None => continue,
        };
        while looks_like_type(opcode_token) && !opcode_token.contains('(') {
            match tokens.next() {
                Some(t) => opcode_token = t,
                None => break,
            }
        }
        // A tuple type like `(f32[2]{0},` starts with '(' but is still a
        // type; the opcode is the first token containing '(' that also
        // has a name prefix (e.g. `tuple(`), or a bare identifier.
        if opcode_token.starts_with('(') {
            let mut found = None;
            for t in tokens.by_ref() {
                if !looks_like_type(t) || (t.contains('(') && !t.starts_with('(')) {
                    found = Some(t);
                    break;
                }
            }
            match found {
                Some(t) => opcode_token = t,
                None => continue,
            }
        }
        let opcode = opcode_token.split('(').next().unwrap_or("").trim_start_matches('%');
        if opcode.is_empty() {
            continue;
        }
        stats.instructions += 1;
        *stats.opcodes.entry(opcode.to_string()).or_insert(0) += 1;
        if opcode == "parameter" && in_entry {
            stats.parameters += 1;
        }
    }
    stats
}

pub fn inspect_file(path: impl AsRef<Path>) -> Result<HloStats> {
    let text = std::fs::read_to_string(path.as_ref())
        .with_context(|| format!("reading {:?}", path.as_ref()))?;
    Ok(parse_hlo_text(&text))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"HloModule jit_fn, entry_computation_layout={(f32[2,2]{1,0})->f32[2,2]{1,0}}

ENTRY %main.4 (Arg_0.1: f32[2,2]) -> f32[2,2] {
  %Arg_0.1 = f32[2,2]{1,0} parameter(0)
  %dot.2 = f32[2,2]{1,0} dot(f32[2,2]{1,0} %Arg_0.1, f32[2,2]{1,0} %Arg_0.1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %add.3 = f32[2,2]{1,0} add(f32[2,2]{1,0} %dot.2, f32[2,2]{1,0} %Arg_0.1)
}
"#;

    #[test]
    fn parses_sample_module() {
        let s = parse_hlo_text(SAMPLE);
        assert_eq!(s.module_name, "jit_fn");
        assert_eq!(s.computations, 1);
        assert_eq!(s.count("parameter"), 1);
        assert_eq!(s.count("dot"), 1);
        assert_eq!(s.count("add"), 1);
        assert_eq!(s.instructions, 3);
    }

    #[test]
    fn real_artifacts_have_expected_structure() {
        // Only meaningful after `make artifacts`; skip otherwise.
        let dir = crate::runtime::Manifest::default_dir();
        let Ok(manifest) = crate::runtime::Manifest::load(&dir) else {
            eprintln!("SKIP: artifacts not built");
            return;
        };
        let grad_mlp = inspect_file(manifest.spec("grad_mlp").unwrap().path.clone()).unwrap();
        // The hand-written backward has 5 matmuls (fwd: 2, bwd: 3); XLA
        // merges transposed-operand pairs so the lowered module may
        // carry fewer dots, but never fewer than the 3 independent
        // contractions — and recomputation would push it well above 8.
        let dots = grad_mlp.count("dot");
        assert!(
            (3..=8).contains(&dots),
            "grad_mlp has {dots} dots, expected 3..=8 (5 written, XLA may merge/split)"
        );
        assert_eq!(grad_mlp.parameters, 3, "theta, x, y");

        let combine = inspect_file(manifest.spec("combine_linear").unwrap().path.clone()).unwrap();
        assert!(combine.count("dot") >= 1);
        assert_eq!(combine.parameters, 2);
    }

    #[test]
    fn empty_text_parses_to_zero() {
        let s = parse_hlo_text("");
        assert_eq!(s.instructions, 0);
        assert_eq!(s.computations, 0);
    }
}
