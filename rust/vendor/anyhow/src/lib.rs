//! Offline shim of the `anyhow` error-handling API.
//!
//! The build container has no crates.io access, so this vendored crate
//! provides the subset of anyhow that gradcode uses with the same
//! semantics:
//!
//! * [`Error`] — an opaque error value holding a message and a context
//!   chain. Like real anyhow, it deliberately does **not** implement
//!   `std::error::Error`, which is what makes the blanket
//!   `From<E: std::error::Error>` impl coherent.
//! * [`Result<T>`] — alias with `Error` as the default error type.
//! * [`anyhow!`] / [`bail!`] — ad-hoc error construction.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`, wrapping the prior error one level deeper.
//!
//! Display follows anyhow's convention: `{}` prints the outermost
//! message only, `{:#}` prints the whole chain separated by `": "`, and
//! `{:?}` prints the message plus a `Caused by:` list.

use std::fmt;

/// Opaque error: outermost message plus the chain of underlying causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `anyhow::Result<T>` — `std::result::Result` with [`Error`] default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// Iterate the chain outermost-first (message strings).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        ChainIter { next: Some(self) }
    }
}

struct ChainIter<'a> {
    next: Option<&'a Error>,
}

impl<'a> Iterator for ChainIter<'a> {
    type Item = &'a str;
    fn next(&mut self) -> Option<&'a str> {
        let cur = self.next?;
        self.next = cur.source.as_deref();
        Some(&cur.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

/// Any std error converts into [`Error`], preserving its source chain
/// as context levels (this is what makes `?` work on io/parse errors).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err = Error { msg: msgs.pop().expect("nonempty"), source: None };
        while let Some(m) = msgs.pop() {
            err = Error { msg: m, source: Some(Box::new(err)) };
        }
        err
    }
}

/// `.context(..)` / `.with_context(..)` on fallible values.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false (anyhow parity).
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Error::msg("inner").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[test]
    fn question_mark_on_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{}", f().unwrap_err()), "gone");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading file").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading file: gone");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
    }

    #[test]
    fn bail_and_anyhow_macros() {
        fn f(x: u32) -> Result<u32> {
            if x == 0 {
                bail!("zero not allowed (got {x})");
            }
            Ok(x)
        }
        assert!(f(1).is_ok());
        assert_eq!(format!("{}", f(0).unwrap_err()), "zero not allowed (got 0)");
        let e = anyhow!("code {}", 7);
        assert_eq!(format!("{e}"), "code 7");
    }

    #[test]
    fn chain_iterates_outermost_first() {
        let e = Error::msg("a").context("b").context("c");
        let parts: Vec<&str> = e.chain().collect();
        assert_eq!(parts, vec!["c", "b", "a"]);
    }
}
