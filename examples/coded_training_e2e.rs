//! End-to-end driver (EXP-E2E in DESIGN.md): train a model through the
//! FULL three-layer stack and log the loss curve.
//!
//!     make artifacts && cargo run --release --example coded_training_e2e
//!
//! Layers exercised per step: Pallas-kernel HLO (L1) inside the JAX
//! partition-gradient graph (L2), executed by the PJRT engine pool and
//! coordinated — codes, stragglers, deadline, decode — in Rust (L3).
//! Falls back to the native backend (same math) if artifacts are absent.
//!
//! Compares FRC / BGC / rBGC against the uncoded baselines the paper's
//! intro motivates: wait-for-all (no stragglers tolerated) and
//! ignore-stragglers (drop their gradients entirely).

use gradcode::codes::Scheme;
use gradcode::coordinator::{DecoderKind, ModelKind};
use gradcode::runtime::{Backend, EnginePool, LinearDims, Manifest, MlpDims};
use gradcode::stragglers::{DeadlinePolicy, LatencyModel};
use gradcode::training::{train, TrainConfig};

fn backend() -> (Option<EnginePool>, Backend) {
    match Manifest::load(Manifest::default_dir()) {
        Ok(m) => {
            let pool = EnginePool::start(m, 4).expect("engine pool");
            let b = Backend::Pjrt(pool.handle());
            eprintln!("backend: pjrt ({} engines)", 4);
            (Some(pool), b)
        }
        Err(e) => {
            eprintln!("backend: native (pjrt unavailable: {e})");
            (
                None,
                Backend::Native {
                    linear: LinearDims { m: 32, d: 64 },
                    mlp: MlpDims { m: 32, d_in: 32, d_hidden: 64, d_out: 16, flat_dim: 3152 },
                    s_max: 10,
                },
            )
        }
    }
}

fn run(
    b: &Backend,
    label: &str,
    scheme: Scheme,
    s: usize,
    r: usize,
    decoder: DecoderKind,
    steps: usize,
) {
    let k = 100;
    let mut cfg = TrainConfig::new(scheme, k, s, ModelKind::Mlp);
    cfg.steps = steps;
    cfg.lr = 2.0;
    cfg.coordinator.decoder = decoder;
    cfg.coordinator.seed = 7;
    // Heavy-tailed worker latencies: the classic straggler regime.
    cfg.coordinator.latency = LatencyModel::Pareto { scale: 0.05, shape: 1.3 };
    cfg.coordinator.deadline = DeadlinePolicy::FastestR(r);

    let t0 = std::time::Instant::now();
    let out = train(b, &cfg).expect("training failed");
    let wall = t0.elapsed().as_secs_f64();
    let h = &out.history;
    println!(
        "{label:<28} loss {:.4} -> {:.4}   decode-err/k {:.4}   virt-gather {:.1}s   wall {:.1}s",
        h.rounds[0].loss,
        h.final_loss(),
        h.mean_decode_err() / k as f64,
        h.total_gather_time(),
        wall
    );
    // Dump the full curve for the headline run.
    if label.starts_with("FRC") {
        eprintln!("--- loss curve ({label}) ---");
        for m in h.rounds.iter().step_by(usize::max(1, h.rounds.len() / 20)) {
            eprintln!("  step {:>4}  loss {:.5}  survivors {}", m.round, m.loss, m.survivors);
        }
    }
}

fn main() {
    let (_pool, b) = backend();
    let steps = std::env::var("E2E_STEPS").ok().and_then(|v| v.parse().ok()).unwrap_or(200);
    let k = 100;
    let r = 80; // tolerate 20% stragglers per round

    println!(
        "== coded MLP training: k={k} partitions, {} params, {} steps, 20% stragglers ==",
        b.mlp_dims().flat_dim,
        steps
    );

    // Coded schemes: compute s partitions per worker, decode around the
    // stragglers.
    run(&b, "FRC s=10 / one-step", Scheme::Frc, 10, r, DecoderKind::OneStep, steps);
    run(&b, "FRC s=10 / optimal", Scheme::Frc, 10, r, DecoderKind::Optimal, steps);
    run(&b, "BGC s=10 / one-step", Scheme::Bgc, 10, r, DecoderKind::OneStep, steps);
    run(&b, "rBGC s=10 / one-step", Scheme::Rbgc, 10, r, DecoderKind::OneStep, steps);
    run(&b, "s-regular s=10 / one-step", Scheme::RegularGraph, 10, r, DecoderKind::OneStep, steps);

    // Baselines: uncoded (cyclic with s=1 is the identity assignment).
    // wait-all: no straggler tolerance — gather time balloons under the
    // Pareto tail; ignore-stragglers: fast but biased gradients.
    run(&b, "uncoded / wait-all", Scheme::Cyclic, 1, k, DecoderKind::OneStep, steps);
    run(&b, "uncoded / ignore-stragglers", Scheme::Cyclic, 1, r, DecoderKind::OneStep, steps);

    println!(
        "\nReading: coded schemes keep the virt-gather time of the r-fastest\n\
         workers (like ignore-stragglers) while their decode error — and\n\
         hence final loss — tracks the wait-all baseline. That trade-off\n\
         is the paper's thesis."
    );
}
