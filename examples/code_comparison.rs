//! Code-comparison sweep: the paper's Figures 2-4 at the command line.
//!
//!     cargo run --release --example code_comparison [trials]
//!
//! Prints the one-step and optimal decoding error of FRC / BGC / rBGC /
//! s-regular / cyclic codes across the straggler fraction δ, plus the
//! decode wall-time per scheme — the decoding-complexity-vs-accuracy
//! trade-off the paper's §6 discusses.

use std::time::Instant;

use gradcode::codes::Scheme;
use gradcode::decode::{OneStepDecoder, OptimalDecoder};
use gradcode::sim::MonteCarlo;
use gradcode::util::Rng;

fn main() {
    let trials: usize = std::env::args().nth(1).and_then(|v| v.parse().ok()).unwrap_or(500);
    let (k, s) = (100usize, 10usize);
    let deltas = [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8];
    let schemes =
        [Scheme::Frc, Scheme::Bgc, Scheme::Rbgc, Scheme::RegularGraph, Scheme::Cyclic];

    println!("k={k}, s={s}, {trials} trials per point\n");

    for &kind in &["one-step", "optimal"] {
        println!("== {kind} decoding error / k ==");
        print!("{:<10}", "delta");
        for scheme in &schemes {
            print!("{:>11}", scheme.name());
        }
        println!();
        for &delta in &deltas {
            let r = (((1.0 - delta) * k as f64).round() as usize).max(1);
            print!("{delta:<10.1}");
            for &scheme in &schemes {
                let mc = MonteCarlo::new(trials, 1234);
                let mean = mc.mean(|rng| {
                    let g = scheme.build(k, k, s).assignment(rng);
                    let a = g.select_columns(&rng.sample_indices(k, r));
                    match kind {
                        "one-step" => OneStepDecoder::canonical(k, r, s).err1(&a),
                        _ => OptimalDecoder::new().err(&a),
                    }
                });
                print!("{:>11.4}", mean / k as f64);
            }
            println!();
        }
        println!();
    }

    // Decode cost: the complexity side of the trade-off.
    println!("== decode wall-time per call (k={k}, r=80, s={s}) ==");
    let r = 80;
    let mut rng = Rng::new(5);
    for &scheme in &schemes {
        let g = scheme.build(k, k, s).assignment(&mut rng);
        let a = g.select_columns(&rng.sample_indices(k, r));
        let reps = 200;
        let t0 = Instant::now();
        let mut acc = 0.0;
        for _ in 0..reps {
            acc += OneStepDecoder::canonical(k, r, s).err1(&a);
        }
        let one_t = t0.elapsed().as_secs_f64() / reps as f64;
        let t1 = Instant::now();
        for _ in 0..reps {
            acc += OptimalDecoder::new().err(&a);
        }
        let opt_t = t1.elapsed().as_secs_f64() / reps as f64;
        std::hint::black_box(acc);
        println!(
            "  {:<12} one-step {:>8.1}ns   optimal {:>9.1}us   ratio {:>6.0}x",
            scheme.name(),
            one_t * 1e9,
            opt_t * 1e6,
            opt_t / one_t
        );
    }
    println!("\nShapes to expect (paper §6): FRC ≈ s-regular ≪ BGC under one-step;\nFRC ≪ everything under optimal decoding; one-step is orders of\nmagnitude cheaper — the complexity/accuracy trade-off.");
}
