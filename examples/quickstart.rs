//! Quickstart: build a gradient code, knock out stragglers, decode.
//!
//!     cargo run --release --example quickstart
//!
//! Walks the public API end to end in ~50 lines: code construction,
//! straggler sampling, both decoders, and the error guarantee of
//! eq. (2.3).

use gradcode::codes::Scheme;
use gradcode::decode::{Decoder, OneStepDecoder, OptimalDecoder};
use gradcode::stragglers::{StragglerModel, UniformStragglers};
use gradcode::util::Rng;

fn main() {
    let (k, s, delta) = (100usize, 10usize, 0.3f64);
    let mut rng = Rng::new(42);

    println!("gradcode quickstart: k={k} tasks, s={s} tasks/worker, {:.0}% stragglers\n", delta * 100.0);

    for scheme in [Scheme::Frc, Scheme::Bgc, Scheme::Rbgc, Scheme::RegularGraph] {
        // 1. Build the assignment matrix G (k x n; here n = k).
        let code = scheme.build(k, k, s);
        let g = code.assignment(&mut rng);

        // 2. Random stragglers: keep r = (1-δ)k workers.
        let model = UniformStragglers::new(delta);
        let survivors = model.non_stragglers(k, &mut rng);
        let a = g.select_columns(&survivors);
        let r = survivors.len();

        // 3. Decode with both of the paper's algorithms.
        let one_step = OneStepDecoder::canonical(k, r, s);
        let optimal = OptimalDecoder::new();
        let err1 = one_step.err1(&a);
        let err = optimal.err(&a);

        // 4. The weights are what a master actually applies to messages.
        let weights = optimal.weights(&a);
        assert_eq!(weights.len(), r);

        println!(
            "{:<10}  err1(A)/k = {:.4}   err(A)/k = {:.4}   (one-step >= optimal: {})",
            scheme.name(),
            err1 / k as f64,
            err / k as f64,
            err1 >= err - 1e-9
        );
    }

    println!(
        "\nInterpretation: the decoded gradient ĝ satisfies\n  \
         |f^T A x - f^T 1_k|^2 <= ||f||^2 * err(A)        (paper eq. 2.3)\n\
         so err(A)/k is the multiplicative accuracy loss from stragglers."
    );
}
