//! Adversarial straggler analysis (paper §4):
//!
//!     cargo run --release --example adversarial_analysis
//!
//! 1. The Thm-10 linear-time attack on FRC (err = k - r exactly).
//! 2. Polynomial heuristics (greedy, local search) against every code —
//!    randomized codes (BGC/rBGC) blunt the attack, FRC shatters.
//! 3. The Thm-11 NP-hardness witness: the DkS → r-ASP reduction's
//!    objective identity, plus the heuristic-vs-exhaustive gap on small
//!    instances.

use gradcode::adversary::{
    asp_objective, dks_to_asp, exhaustive_worst_case, frc_worst_stragglers, greedy_dks,
    greedy_stragglers, local_search_stragglers, objective_identity_gap,
};
use gradcode::codes::Scheme;
use gradcode::decode::OptimalDecoder;
use gradcode::graph::random_regular_graph;
use gradcode::util::Rng;

fn main() {
    let mut rng = Rng::new(2017);

    // ---------------------------------------------------- 1. Thm 10
    println!("== 1. Thm 10: the FRC block attack ==");
    let (k, s) = (100usize, 10usize);
    let g = Scheme::Frc.build(k, k, s).assignment(&mut rng);
    for r in [50usize, 70, 80, 90] {
        let ns = frc_worst_stragglers(&g, r);
        let adv = OptimalDecoder::new().err(&g.select_columns(&ns));
        let rand = {
            let mut acc = 0.0;
            for _ in 0..50 {
                acc += OptimalDecoder::new().err(&g.select_columns(&rng.sample_indices(k, r)));
            }
            acc / 50.0
        };
        println!(
            "  r={r:>3}: adversarial err = {adv:>5.1} (theory {})   random-straggler mean = {rand:.4}",
            k - r
        );
    }

    // ------------------------------------------- 2. heuristics per code
    println!("\n== 2. polynomial adversaries vs every code (k=100, s=10, r=80) ==");
    let r = 80;
    let rho = k as f64 / (r as f64 * s as f64);
    println!(
        "  {:<12} {:>10} {:>12} {:>12} {:>14}",
        "scheme", "random", "block-attack", "greedy", "local-search"
    );
    for scheme in [Scheme::Frc, Scheme::Bgc, Scheme::Rbgc, Scheme::RegularGraph, Scheme::Cyclic] {
        let g = scheme.build(k, k, s).assignment(&mut rng);
        let opt_err = |ns: &[usize]| OptimalDecoder::new().err(&g.select_columns(ns));
        let rand = opt_err(&rng.sample_indices(k, r));
        let block = opt_err(&frc_worst_stragglers(&g, r));
        let greedy = opt_err(&greedy_stragglers(&g, r, rho));
        let ls = opt_err(&local_search_stragglers(&g, r, rho, 3));
        println!(
            "  {:<12} {rand:>10.3} {block:>12.3} {greedy:>12.3} {ls:>14.3}",
            scheme.name()
        );
    }
    println!("  (optimal-decode err of the survivor set each adversary leaves behind)");

    // ---------------------------------------------------- 3. Thm 11
    println!("\n== 3. Thm 11: DkS -> r-ASP reduction (NP-hardness witness) ==");
    let d = 4;
    let graph = random_regular_graph(16, d, &mut rng);
    let inst = dks_to_asp(&graph, d);
    let rho_red = 0.5;
    let mut max_gap = 0.0f64;
    for t in 1..=12 {
        let subset = rng.sample_indices(16, t);
        max_gap = max_gap.max(objective_identity_gap(&inst, &graph, &subset, rho_red));
    }
    println!("  objective identity |lhs - rhs| over random subsets: {max_gap:.2e} (eq. 4.2/4.3)");

    // Densest-subgraph view: greedy DkS and greedy ASP chase the same set.
    let t = 8;
    let dks_set = greedy_dks(&graph, t);
    println!(
        "  greedy DkS t={t}: e(S) = {} edges (graph has {})",
        graph.edges_within(&dks_set),
        graph.edge_count()
    );

    // Heuristic vs exhaustive on a small BGC.
    let (ks, ss, rs) = (14usize, 3usize, 9usize);
    let rho_s = ks as f64 / (rs as f64 * ss as f64);
    let gm = Scheme::Bgc.build(ks, ks, ss).assignment(&mut rng);
    let (_, exact) = exhaustive_worst_case(&gm, rs, rho_s);
    let gr = asp_objective(&gm, &greedy_stragglers(&gm, rs, rho_s), rho_s);
    let lso = asp_objective(&gm, &local_search_stragglers(&gm, rs, rho_s, 10), rho_s);
    println!(
        "  small-BGC worst case: exhaustive {exact:.3}, greedy {gr:.3} ({:.0}%), local-search {lso:.3} ({:.0}%)",
        100.0 * gr / exact,
        100.0 * lso / exact
    );
    println!(
        "\nReading: FRC's worst case is catastrophic and easy to find; the\n\
         random codes leave polynomial adversaries near the random-straggler\n\
         regime — and finding their true worst case is NP-hard (Thm 11)."
    );
}
