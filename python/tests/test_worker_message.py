"""Fused L2 worker-message modules vs composed oracle.

msg_linear / msg_mlp fuse s partition gradients + the coded combine in
one module (the §Perf optimization); they must equal the composition of
the individual reference functions exactly.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import ref_coded_combine, ref_linear_grad
from compile.model import (
    MlpDims,
    _unflatten,
    linear_worker_message,
    mlp_partition_grad,
    mlp_worker_message,
)

F32 = jnp.float32


def _rand(key, *shape):
    return jax.random.normal(key, shape, F32)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    s=st.integers(1, 6),
    m=st.sampled_from([4, 8]),
    d=st.sampled_from([4, 16]),
)
def test_linear_message_matches_composition(seed, s, m, d):
    kw, kx, ky, kc = jax.random.split(jax.random.PRNGKey(seed), 4)
    w = _rand(kw, d)
    xs = _rand(kx, s, m, d)
    ys = _rand(ky, s, m)
    coeffs = _rand(kc, s)
    (got,) = linear_worker_message(w, xs, ys, coeffs)
    grads = jnp.stack([ref_linear_grad(xs[i], w, ys[i]) for i in range(s)])
    want = ref_coded_combine(grads, coeffs)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_linear_message_zero_coeff_drops_shard():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    w = _rand(k1, 8)
    xs = _rand(k2, 3, 4, 8)
    ys = _rand(k3, 3, 4)
    full = linear_worker_message(w, xs, ys, jnp.array([1.0, 0.0, 1.0], F32))[0]
    # Replacing the dropped shard with garbage must not change the message.
    xs2 = xs.at[1].set(99.0)
    alt = linear_worker_message(w, xs2, ys, jnp.array([1.0, 0.0, 1.0], F32))[0]
    np.testing.assert_allclose(full, alt, rtol=1e-6)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), s=st.integers(1, 4))
def test_mlp_message_matches_composition(seed, s):
    dims = MlpDims(m=4, d_in=4, d_hidden=6, d_out=2)
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    theta = 0.1 * _rand(k1, dims.flat_dim)
    xs = _rand(k2, s, dims.m, dims.d_in)
    ys = _rand(k3, s, dims.m, dims.d_out)
    coeffs = _rand(k4, s)
    losses, msg = mlp_worker_message(theta, xs, ys, coeffs, dims=dims)

    ref_losses = []
    grads = []
    for i in range(s):
        loss, flat = mlp_partition_grad(theta, xs[i], ys[i], dims=dims)
        ref_losses.append(loss)
        grads.append(flat)
    np.testing.assert_allclose(losses, jnp.stack(ref_losses), rtol=1e-5)
    want = ref_coded_combine(jnp.stack(grads), coeffs)
    np.testing.assert_allclose(msg, want, rtol=2e-3, atol=2e-5)


def test_mlp_message_losses_are_per_shard():
    dims = MlpDims(m=4, d_in=3, d_hidden=4, d_out=2)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    theta = 0.1 * _rand(k1, dims.flat_dim)
    xs = _rand(k2, 2, dims.m, dims.d_in)
    ys = _rand(k3, 2, dims.m, dims.d_out)
    losses, _ = mlp_worker_message(theta, xs, ys, jnp.ones(2, F32), dims=dims)
    for i in range(2):
        loss_i, _ = mlp_partition_grad(theta, xs[i], ys[i], dims=dims)
        np.testing.assert_allclose(losses[i], loss_i, rtol=1e-6)


def test_unflatten_used_by_message_path():
    # Guard the parameter layout contract between python and rust
    # (native.rs splits theta in the same w1|b1|w2|b2 order).
    dims = MlpDims(m=2, d_in=2, d_hidden=3, d_out=2)
    theta = jnp.arange(dims.flat_dim, dtype=F32)
    w1, b1, w2, b2 = _unflatten(theta, dims)
    assert float(w1[0, 0]) == 0.0
    assert float(b1[0]) == dims.d_in * dims.d_hidden
    assert float(w2[0, 0]) == dims.d_in * dims.d_hidden + dims.d_hidden
    assert float(b2[-1]) == dims.flat_dim - 1
