"""L2 model vs autodiff oracle: the hand-written MLP backward must match
jax.grad of the pure-jnp reference model exactly (up to f32 tolerance)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import ref_mlp_loss
from compile.model import (
    LinearDims,
    MlpDims,
    _unflatten,
    linear_partition_grad,
    mlp_partition_grad,
)

F32 = jnp.float32


def _mlp_case(seed, dims):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    theta = 0.1 * jax.random.normal(k1, (dims.flat_dim,), F32)
    x = jax.random.normal(k2, (dims.m, dims.d_in), F32)
    y = jax.random.normal(k3, (dims.m, dims.d_out), F32)
    return theta, x, y


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    m=st.sampled_from([4, 8, 16]),
    d_in=st.sampled_from([4, 8, 16]),
    d_hidden=st.sampled_from([4, 16]),
    d_out=st.sampled_from([4, 8]),
)
def test_mlp_grad_matches_autodiff(seed, m, d_in, d_hidden, d_out):
    dims = MlpDims(m=m, d_in=d_in, d_hidden=d_hidden, d_out=d_out)
    theta, x, y = _mlp_case(seed, dims)
    loss, flat = mlp_partition_grad(theta, x, y, dims=dims)

    params = _unflatten(theta, dims)
    ref_loss = ref_mlp_loss(params, x, y)
    ref_flat = jnp.concatenate(
        [g.ravel() for g in jax.grad(ref_mlp_loss)(params, x, y)]
    )
    np.testing.assert_allclose(loss, ref_loss, rtol=1e-5)
    np.testing.assert_allclose(flat, ref_flat, rtol=2e-3, atol=2e-5)


def test_mlp_flat_dim_accounts_every_parameter():
    dims = MlpDims(m=8, d_in=5, d_hidden=7, d_out=3)
    assert dims.flat_dim == 5 * 7 + 7 + 7 * 3 + 3


def test_unflatten_roundtrip():
    dims = MlpDims(m=8, d_in=3, d_hidden=4, d_out=2)
    theta = jnp.arange(dims.flat_dim, dtype=F32)
    w1, b1, w2, b2 = _unflatten(theta, dims)
    assert w1.shape == (3, 4) and b1.shape == (4,)
    assert w2.shape == (4, 2) and b2.shape == (2,)
    back = jnp.concatenate([w1.ravel(), b1, w2.ravel(), b2])
    np.testing.assert_array_equal(back, theta)


def test_mlp_gradient_descends():
    # A few hand-rolled GD steps on the flat gradient must reduce the loss.
    dims = MlpDims(m=16, d_in=8, d_hidden=16, d_out=4)
    theta, x, y = _mlp_case(123, dims)
    loss0, flat = mlp_partition_grad(theta, x, y, dims=dims)
    for _ in range(20):
        theta = theta - 0.5 * flat
        loss, flat = mlp_partition_grad(theta, x, y, dims=dims)
    assert loss < loss0


def test_linear_partition_grad_is_shard_gradient():
    lin = LinearDims(m=16, d=8)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(k1, (lin.m, lin.d), F32)
    w = jax.random.normal(k2, (lin.d,), F32)
    y = jax.random.normal(k3, (lin.m,), F32)
    (g,) = linear_partition_grad(x, w, y)
    # oracle: grad of 0.5/m * ||Xw - y||^2
    loss = lambda w_: 0.5 / lin.m * jnp.sum((x @ w_ - y) ** 2)
    np.testing.assert_allclose(g, jax.grad(loss)(w), rtol=2e-4, atol=2e-5)
