"""L1 kernel vs pure-jnp oracle — the core correctness signal.

hypothesis sweeps shapes/seeds; every Pallas kernel must match ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import coded_combine, linear_grad, matmul
from compile.kernels.ref import ref_coded_combine, ref_linear_grad, ref_matmul

F32 = jnp.float32


def _rand(key, *shape):
    return jax.random.normal(key, shape, F32)


def _keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


# ---------------------------------------------------------------- linear_grad

@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    mt=st.integers(1, 6),  # m = mt * block_m
    d=st.sampled_from([1, 3, 8, 32, 64]),
    block_m=st.sampled_from([1, 2, 4, 8]),
)
def test_linear_grad_matches_ref(seed, mt, d, block_m):
    m = mt * block_m
    kx, kw, ky = _keys(seed, 3)
    x, w, y = _rand(kx, m, d), _rand(kw, d), _rand(ky, m)
    got = linear_grad(x, w, y, block_m=block_m)
    np.testing.assert_allclose(got, ref_linear_grad(x, w, y), rtol=2e-4, atol=2e-5)


def test_linear_grad_zero_weights():
    kx, ky = _keys(0, 2)
    x, y = _rand(kx, 16, 8), _rand(ky, 16)
    got = linear_grad(x, jnp.zeros(8, F32), y)
    np.testing.assert_allclose(got, -x.T @ y / 16, rtol=1e-5, atol=1e-6)


def test_linear_grad_at_solution_is_zero():
    # y = X w* exactly => gradient at w* is 0.
    kx, kw = _keys(1, 2)
    x, w = _rand(kx, 32, 8), _rand(kw, 8)
    y = x @ w
    got = linear_grad(x, w, y)
    np.testing.assert_allclose(got, jnp.zeros(8), atol=1e-5)


def test_linear_grad_rejects_bad_block():
    kx, kw, ky = _keys(2, 3)
    with pytest.raises(ValueError):
        linear_grad(_rand(kx, 10, 4), _rand(kw, 4), _rand(ky, 10), block_m=3)


# --------------------------------------------------------------------- matmul

@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    mt=st.integers(1, 4),
    nt=st.integers(1, 4),
    kt=st.integers(1, 4),
    blk=st.sampled_from([1, 2, 4, 8, 16]),
)
def test_matmul_matches_ref(seed, mt, nt, kt, blk):
    m, n, k = mt * blk, nt * blk, kt * blk
    ka, kb = _keys(seed, 2)
    a, b = _rand(ka, m, k), _rand(kb, k, n)
    got = matmul(a, b, block_m=blk, block_n=blk, block_k=blk)
    np.testing.assert_allclose(got, ref_matmul(a, b), rtol=2e-4, atol=2e-5)


def test_matmul_identity():
    (ka,) = _keys(3, 1)
    a = _rand(ka, 16, 16)
    np.testing.assert_allclose(matmul(a, jnp.eye(16, dtype=F32)), a, rtol=1e-6)


def test_matmul_contraction_mismatch():
    ka, kb = _keys(4, 2)
    with pytest.raises(ValueError):
        matmul(_rand(ka, 8, 4), _rand(kb, 8, 8))


def test_matmul_block_larger_than_dim_is_clamped():
    ka, kb = _keys(5, 2)
    a, b = _rand(ka, 4, 4), _rand(kb, 4, 4)
    got = matmul(a, b, block_m=64, block_n=64, block_k=64)
    np.testing.assert_allclose(got, a @ b, rtol=2e-4, atol=2e-5)


# -------------------------------------------------------------- coded_combine

@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    s=st.integers(1, 12),
    dt=st.integers(1, 6),
    block_d=st.sampled_from([1, 2, 8, 32]),
)
def test_combine_matches_ref(seed, s, dt, block_d):
    d = dt * block_d
    kg, kc = _keys(seed, 2)
    grads, coeffs = _rand(kg, s, d), _rand(kc, s)
    got = coded_combine(grads, coeffs, block_d=block_d)
    np.testing.assert_allclose(got, ref_coded_combine(grads, coeffs), rtol=2e-4, atol=2e-5)


def test_combine_zero_coeffs_gives_zero():
    (kg,) = _keys(6, 1)
    grads = _rand(kg, 5, 64)
    got = coded_combine(grads, jnp.zeros(5, F32))
    np.testing.assert_allclose(got, jnp.zeros(64), atol=0)


def test_combine_onehot_selects_row():
    (kg,) = _keys(7, 1)
    grads = _rand(kg, 5, 64)
    c = jnp.zeros(5, F32).at[3].set(1.0)
    np.testing.assert_allclose(coded_combine(grads, c), grads[3], rtol=1e-6)


def test_combine_all_ones_is_sum():
    # This is exactly the boolean-G worker message (FRC/BGC coefficients).
    (kg,) = _keys(8, 1)
    grads = _rand(kg, 7, 32)
    got = coded_combine(grads, jnp.ones(7, F32), block_d=16)
    np.testing.assert_allclose(got, grads.sum(axis=0), rtol=2e-4, atol=2e-5)
