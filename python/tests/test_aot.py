"""AOT pipeline tests: artifacts exist, are HLO text, shapes in manifest."""

import json
import os

import pytest

from compile.aot import build_artifacts
from compile.model import LinearDims, MlpDims

LIN = LinearDims(m=8, d=16)
MLP = MlpDims(m=8, d_in=8, d_hidden=16, d_out=4)
S_MAX = 4


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = build_artifacts(str(out), LIN, MLP, S_MAX)
    return out, manifest


def test_all_artifacts_emitted(built):
    out, manifest = built
    expected = {
        "grad_linear",
        "grad_mlp",
        "combine_linear",
        "combine_mlp",
        "msg_linear",
        "msg_mlp",
    }
    assert set(manifest["artifacts"]) == expected
    for meta in manifest["artifacts"].values():
        path = out / meta["file"]
        assert path.exists() and path.stat().st_size > 200


def test_artifacts_are_hlo_text_not_proto(built):
    out, manifest = built
    for meta in manifest["artifacts"].values():
        head = (out / meta["file"]).read_text()[:200]
        assert "HloModule" in head  # text, parseable by HloModuleProto::from_text


def test_manifest_shapes(built):
    out, manifest = built
    m = json.loads((out / "manifest.json").read_text())
    assert m["linear"] == {"m": LIN.m, "d": LIN.d}
    assert m["mlp"]["flat_dim"] == MLP.flat_dim
    assert m["s_max"] == S_MAX
    gl = m["artifacts"]["grad_linear"]["inputs"]
    assert gl == [[LIN.m, LIN.d], [LIN.d], [LIN.m]]
    cm = m["artifacts"]["combine_mlp"]["inputs"]
    assert cm == [[S_MAX, MLP.flat_dim], [S_MAX]]


def test_hlo_entry_returns_tuple(built):
    # return_tuple=True => ROOT of entry computation is a tuple; the Rust
    # side unconditionally unwraps with to_tuple().
    out, manifest = built
    text = (out / manifest["artifacts"]["grad_mlp"]["file"]).read_text()
    assert "tuple(" in text or "ROOT" in text
