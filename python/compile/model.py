"""L2: the per-worker compute graphs, built on the L1 Pallas kernels.

Three jittable functions are AOT-lowered to HLO text by ``aot.py``:

* ``linear_partition_grad`` — the paper's f_i for least squares: the
  gradient of one data shard. One Pallas linear_grad call.
* ``mlp_partition_grad``    — f_i for a 2-layer tanh MLP (MSE loss):
  forward + *manual* backward, with every matmul routed through the
  tiled Pallas matmul kernel (pallas_call has no autodiff rule, and
  manual backprop is what a production AOT path ships anyway). Returns
  (loss, flat_grad) so the Rust side logs loss curves for free.
* ``coded_combine_message`` — the worker->master message: the linear
  combination of its s gradients with its column of G as coefficients.

All shapes are static; the Rust runtime reads them from
``artifacts/manifest.json``.
"""

from dataclasses import dataclass

import jax.numpy as jnp

from .kernels import linear_grad, matmul, coded_combine


@dataclass(frozen=True)
class MlpDims:
    """Static shape bundle for the MLP partition gradient."""

    m: int = 32  # examples per partition shard
    d_in: int = 32
    d_hidden: int = 64
    d_out: int = 16

    @property
    def flat_dim(self) -> int:
        """Length of the flattened (W1, b1, W2, b2) gradient vector."""
        return (
            self.d_in * self.d_hidden
            + self.d_hidden
            + self.d_hidden * self.d_out
            + self.d_out
        )


@dataclass(frozen=True)
class LinearDims:
    """Static shape bundle for the least-squares partition gradient."""

    m: int = 32
    d: int = 64


def linear_partition_grad(x, w, y):
    """g_i = X_i^T (X_i w - y_i) / m — one shard of the full gradient."""
    return (linear_grad(x, w, y),)


def _unflatten(theta, dims: MlpDims):
    """Split the flat parameter vector into (W1, b1, W2, b2)."""
    i = 0
    w1 = theta[i : i + dims.d_in * dims.d_hidden].reshape(dims.d_in, dims.d_hidden)
    i += dims.d_in * dims.d_hidden
    b1 = theta[i : i + dims.d_hidden]
    i += dims.d_hidden
    w2 = theta[i : i + dims.d_hidden * dims.d_out].reshape(dims.d_hidden, dims.d_out)
    i += dims.d_hidden * dims.d_out
    b2 = theta[i : i + dims.d_out]
    return w1, b1, w2, b2


def mlp_partition_grad(theta, x, y, *, dims: MlpDims):
    """(loss, flat_grad) of a 2-layer tanh MLP with MSE loss on one shard.

    Forward:  H = tanh(X W1 + b1);  O = H W2 + b2;  L = mean((O - Y)^2).
    Backward is written out by hand; all five matmuls go through the
    Pallas kernel so the hot path is the tiled MXU schedule end to end.
    """
    w1, b1, w2, b2 = _unflatten(theta, dims)
    m = dims.m

    # Forward
    z1 = matmul(x, w1) + b1  # (m, h)
    h = jnp.tanh(z1)
    o = matmul(h, w2) + b2  # (m, o)
    diff = o - y
    loss = jnp.mean(diff**2)

    # Backward (MSE): dO = 2 (O - Y) / (m * d_out)
    do = (2.0 / (m * dims.d_out)) * diff
    dw2 = matmul(h.T, do)  # (h, o)
    db2 = jnp.sum(do, axis=0)
    dh = matmul(do, w2.T)  # (m, h)
    dz1 = dh * (1.0 - h**2)
    dw1 = matmul(x.T, dz1)  # (in, h)
    db1 = jnp.sum(dz1, axis=0)

    flat = jnp.concatenate([dw1.ravel(), db1, dw2.ravel(), db2])
    return loss, flat


def coded_combine_message(grads, coeffs):
    """The coded message: v = sum_i coeffs[i] * grads[i] (one G column)."""
    return (coded_combine(grads, coeffs),)


def linear_worker_message(w, xs, ys, coeffs):
    """Fused worker round: s partition gradients + coded combine in ONE
    lowered module (one PJRT dispatch per worker per step instead of
    s + 1 — the §Perf L2 optimization; see EXPERIMENTS.md).

    xs: (s, m, d) stacked shards, ys: (s, m), coeffs: (s,).
    Unused slots carry zero shards and zero coefficients.
    """
    s = xs.shape[0]
    grads = jnp.stack([linear_grad(xs[i], w, ys[i]) for i in range(s)])
    return (coded_combine(grads, coeffs),)


def mlp_worker_message(theta, xs, ys, coeffs, *, dims: MlpDims):
    """Fused MLP worker round: per-shard (loss, grad) + coded combine.

    Returns (losses (s,), message (flat_dim,)); the coordinator sums
    only the losses of real (non-padded) tasks.
    """
    s = xs.shape[0]
    losses = []
    grads = []
    for i in range(s):
        loss, flat = mlp_partition_grad(theta, xs[i], ys[i], dims=dims)
        losses.append(loss)
        grads.append(flat)
    return jnp.stack(losses), coded_combine(jnp.stack(grads), coeffs)
