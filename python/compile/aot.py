"""AOT pipeline: lower every L2 entry point to HLO *text* artifacts.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (what
the Rust `xla` crate links) rejects (`proto.id() <= INT_MAX`). The text
parser reassigns ids, so text round-trips cleanly. See
/opt/xla-example/gen_hlo.py.

Run once via ``make artifacts``; Python is never on the request path.

Outputs (under --out-dir, default ../artifacts):
  grad_linear.hlo.txt   (x(m,d), w(d), y(m))          -> (g(d),)
  grad_mlp.hlo.txt      (theta(F), x(m,in), y(m,out)) -> (loss, grad(F))
  combine_linear.hlo.txt(grads(s,d), coeffs(s))       -> (v(d),)
  combine_mlp.hlo.txt   (grads(s,F), coeffs(s))       -> (v(F),)
  manifest.json          all static shapes, for the Rust runtime
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .kernels.ref import ref_coded_combine, ref_linear_grad, ref_mlp_loss
from .model import (
    LinearDims,
    MlpDims,
    _unflatten,
    coded_combine_message,
    linear_partition_grad,
    linear_worker_message,
    mlp_partition_grad,
    mlp_worker_message,
)

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def _selfcheck(lin: LinearDims, mlp: MlpDims, s_max: int) -> None:
    """Refuse to emit artifacts whose numerics disagree with the oracle."""
    key = jax.random.PRNGKey(0)
    kx, kw, ky, kt = jax.random.split(key, 4)

    x = jax.random.normal(kx, (lin.m, lin.d), F32)
    w = jax.random.normal(kw, (lin.d,), F32)
    y = jax.random.normal(ky, (lin.m,), F32)
    (g,) = linear_partition_grad(x, w, y)
    np.testing.assert_allclose(g, ref_linear_grad(x, w, y), rtol=2e-4, atol=2e-5)

    theta = 0.1 * jax.random.normal(kt, (mlp.flat_dim,), F32)
    xm = jax.random.normal(kx, (mlp.m, mlp.d_in), F32)
    ym = jax.random.normal(ky, (mlp.m, mlp.d_out), F32)
    loss, flat = mlp_partition_grad(theta, xm, ym, dims=mlp)
    params = _unflatten(theta, mlp)
    ref_loss = ref_mlp_loss(params, xm, ym)
    ref_flat = jnp.concatenate(
        [p.ravel() for p in jax.grad(ref_mlp_loss)(params, xm, ym)]
    )
    np.testing.assert_allclose(loss, ref_loss, rtol=1e-5)
    np.testing.assert_allclose(flat, ref_flat, rtol=2e-3, atol=2e-5)

    grads = jax.random.normal(kx, (s_max, lin.d), F32)
    coeffs = jax.random.normal(kw, (s_max,), F32)
    (v,) = coded_combine_message(grads, coeffs)
    np.testing.assert_allclose(v, ref_coded_combine(grads, coeffs), rtol=2e-4, atol=2e-5)


def build_artifacts(out_dir: str, lin: LinearDims, mlp: MlpDims, s_max: int) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    _selfcheck(lin, mlp, s_max)

    entries = {
        "grad_linear": (
            linear_partition_grad,
            (_spec(lin.m, lin.d), _spec(lin.d), _spec(lin.m)),
        ),
        "grad_mlp": (
            functools.partial(mlp_partition_grad, dims=mlp),
            (_spec(mlp.flat_dim), _spec(mlp.m, mlp.d_in), _spec(mlp.m, mlp.d_out)),
        ),
        "combine_linear": (
            coded_combine_message,
            (_spec(s_max, lin.d), _spec(s_max)),
        ),
        "combine_mlp": (
            coded_combine_message,
            (_spec(s_max, mlp.flat_dim), _spec(s_max)),
        ),
        # Fused one-dispatch-per-worker rounds (§Perf): s gradients +
        # coded combine lowered into a single module.
        "msg_linear": (
            linear_worker_message,
            (
                _spec(lin.d),
                _spec(s_max, lin.m, lin.d),
                _spec(s_max, lin.m),
                _spec(s_max),
            ),
        ),
        "msg_mlp": (
            functools.partial(mlp_worker_message, dims=mlp),
            (
                _spec(mlp.flat_dim),
                _spec(s_max, mlp.m, mlp.d_in),
                _spec(s_max, mlp.m, mlp.d_out),
                _spec(s_max),
            ),
        ),
    }

    manifest = {
        "format": "hlo-text",
        "dtype": "f32",
        "s_max": s_max,
        "linear": {"m": lin.m, "d": lin.d},
        "mlp": {
            "m": mlp.m,
            "d_in": mlp.d_in,
            "d_hidden": mlp.d_hidden,
            "d_out": mlp.d_out,
            "flat_dim": mlp.flat_dim,
        },
        "artifacts": {},
    }

    for name, (fn, specs) in entries.items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": fname,
            "inputs": [list(s.shape) for s in specs],
        }
        print(f"  {fname}: {len(text)} chars")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--linear-m", type=int, default=32)
    p.add_argument("--linear-d", type=int, default=64)
    p.add_argument("--mlp-m", type=int, default=32)
    p.add_argument("--mlp-din", type=int, default=32)
    p.add_argument("--mlp-hidden", type=int, default=64)
    p.add_argument("--mlp-dout", type=int, default=16)
    p.add_argument("--s-max", type=int, default=10)
    args = p.parse_args()

    lin = LinearDims(m=args.linear_m, d=args.linear_d)
    mlp = MlpDims(
        m=args.mlp_m,
        d_in=args.mlp_din,
        d_hidden=args.mlp_hidden,
        d_out=args.mlp_dout,
    )
    print(f"AOT-lowering to {args.out_dir} (mlp flat_dim={mlp.flat_dim})")
    build_artifacts(args.out_dir, lin, mlp, args.s_max)
    print("AOT done.")


if __name__ == "__main__":
    main()
