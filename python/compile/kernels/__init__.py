"""L1 Pallas kernels (build-time only).

Every kernel here is lowered with ``interpret=True`` so the emitted HLO is
plain XLA ops runnable by the CPU PJRT client the Rust runtime uses. On a
real TPU the same BlockSpecs express the HBM->VMEM schedule; see
DESIGN.md section "Hardware-Adaptation".
"""

from .linear_grad import linear_grad
from .matmul import matmul
from .combine import coded_combine

__all__ = ["linear_grad", "matmul", "coded_combine"]
