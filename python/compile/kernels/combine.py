"""Pallas kernel for the coded message: v = sum_i c_i * g_i.

This is the linear combination each worker sends to the master (the
entries of its column of G are the coefficients c). Stragglers that
finished only some tasks zero the corresponding coefficients, so a single
(s_max, d) artifact serves every worker.

The grid tiles the gradient dimension d; each step contracts the full
coefficient vector against an (s, bd) block of the stacked gradients —
a skinny matvec that maps onto one MXU pass per tile on TPU.
"""

import functools

import jax
from jax.experimental import pallas as pl


def _kernel(g_ref, c_ref, o_ref):
    o_ref[...] = c_ref[...] @ g_ref[...]


@functools.partial(jax.jit, static_argnames=("block_d",))
def coded_combine(grads, coeffs, *, block_d: int = 256):
    """v = coeffs @ grads for grads (s, d), coeffs (s,) -> (d,)."""
    s, d = grads.shape
    if coeffs.shape != (s,):
        raise ValueError(f"coeffs shape {coeffs.shape} != ({s},)")
    # Snap to the largest divisor of d that is <= block_d, so any gradient
    # length works (flat MLP grads are rarely powers of two).
    block_d = min(block_d, d)
    while d % block_d != 0:
        block_d -= 1
    grid = (d // block_d,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((s, block_d), lambda i: (0, i)),
            pl.BlockSpec((s,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_d,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), grads.dtype),
        interpret=True,
    )(grads, coeffs)
