"""Tiled matmul Pallas kernel — the MXU-shaped primitive under the MLP.

Classic (i, j, kk) grid: each step multiplies an (bm, bk) tile of A with a
(bk, bn) tile of B and accumulates into the (bm, bn) output tile, which is
revisited for every kk (output BlockSpec ignores the contraction index).
On TPU this is the canonical MXU systolic schedule; interpret=True lowers
it to plain HLO so the CPU PJRT client can run it.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, o_ref):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += a_ref[...] @ b_ref[...]


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def matmul(a, b, *, block_m: int = 16, block_n: int = 16, block_k: int = 16):
    """C = A @ B with (bm, bn, bk) tiling.

    Shapes: a (m, k), b (k, n); every block size must divide its dim.
    """
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {k} vs {k2}")
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    for dim, blk, name in ((m, block_m, "m"), (n, block_n, "n"), (k, block_k, "k")):
        if dim % blk != 0:
            raise ValueError(f"block_{name}={blk} must divide {name}={dim}")
    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, b)
