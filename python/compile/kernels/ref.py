"""Pure-jnp oracles for every L1 kernel — the correctness ground truth.

pytest asserts allclose(kernel(...), ref_*(...)) across hypothesis-swept
shapes; the AOT pipeline refuses to emit artifacts if the check fails.
"""

import jax.numpy as jnp


def ref_linear_grad(x, w, y):
    """g = X^T (X w - y) / m."""
    m = x.shape[0]
    return x.T @ (x @ w - y) / m


def ref_matmul(a, b):
    return a @ b


def ref_coded_combine(grads, coeffs):
    return coeffs @ grads


def ref_mlp_loss(params, x, y):
    """2-layer tanh MLP, mean-squared error against dense targets."""
    w1, b1, w2, b2 = params
    h = jnp.tanh(x @ w1 + b1)
    o = h @ w2 + b2
    return jnp.mean((o - y) ** 2)
