"""Pallas kernel for the partition-gradient hot-spot g = X^T (X w - y) / m.

This is the f_i of the paper's setup (2.1) when the loss is least squares:
each of the k partitions holds a shard (X_i, y_i) and the worker computes
the shard gradient. The kernel tiles the row dimension of X so each grid
step streams one (bm, d) block of X through the (would-be) MXU twice:
once for the residual r = X w - y and once for the accumulation X^T r.

On TPU the BlockSpec below is exactly the HBM->VMEM double-pass schedule;
under interpret=True it lowers to plain HLO for the CPU PJRT client.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, y_ref, o_ref, *, m_total: int):
    """One row-tile of the two-pass gradient.

    o_ref is mapped to the same (full) block at every grid step, so it
    doubles as the VMEM accumulator (standard Pallas reduction pattern).
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # (bm, d) tile
    r = x @ w_ref[...] - y_ref[...]  # residual on this tile, (bm,)
    o_ref[...] += x.T @ r

    @pl.when(i == pl.num_programs(0) - 1)
    def _finish():
        o_ref[...] = o_ref[...] / m_total


@functools.partial(jax.jit, static_argnames=("block_m",))
def linear_grad(x, w, y, *, block_m: int = 16):
    """g = X^T (X w - y) / m with a row-tiled Pallas kernel.

    Args:
      x: (m, d) float32 design matrix shard.
      w: (d,) float32 model.
      y: (m,) float32 targets.
      block_m: row-tile size; must divide m.
    """
    m, d = x.shape
    block_m = min(block_m, m)
    if m % block_m != 0:
        raise ValueError(f"block_m={block_m} must divide m={m}")
    grid = (m // block_m,)
    return pl.pallas_call(
        functools.partial(_kernel, m_total=m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((block_m,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((d,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((d,), x.dtype),
        interpret=True,
    )(x, w, y)
