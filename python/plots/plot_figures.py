"""Render the paper's Figures 2-5 from the CSVs `make figures` emits.

Usage:  python python/plots/plot_figures.py [results_dir] [out_dir]

Produces fig2.png .. fig5.png with the same panel layout as the paper
(s = 5 left, s = 10 right; Fig. 5 one curve per delta). Pure plotting —
all numbers come from the Rust harness.
"""

import csv
import os
import sys
from collections import defaultdict

import matplotlib

matplotlib.use("Agg")
import matplotlib.pyplot as plt  # noqa: E402


def load(path):
    rows = []
    with open(path) as f:
        for row in csv.DictReader(f):
            row["s"] = int(row["s"])
            row["delta"] = float(row["delta"])
            row["t"] = int(row["t"])
            row["value"] = float(row["value"])
            rows.append(row)
    return rows


def plot_error_vs_delta(rows, title, ylabel, out_path):
    s_values = sorted({r["s"] for r in rows})
    fig, axes = plt.subplots(1, len(s_values), figsize=(6 * len(s_values), 4.2))
    if len(s_values) == 1:
        axes = [axes]
    for ax, s in zip(axes, s_values):
        series = defaultdict(list)
        for r in rows:
            if r["s"] == s:
                series[r["scheme"]].append((r["delta"], r["value"]))
        for scheme, pts in sorted(series.items()):
            pts.sort()
            ax.plot([p[0] for p in pts], [p[1] for p in pts], marker="o", ms=3, label=scheme)
        ax.set_xlabel(r"straggler fraction $\delta$")
        ax.set_ylabel(ylabel)
        ax.set_title(f"{title} (s={s})")
        ax.legend(fontsize=8)
        ax.grid(alpha=0.3)
    fig.tight_layout()
    fig.savefig(out_path, dpi=130)
    plt.close(fig)
    print(f"wrote {out_path}")


def plot_fig5(rows, out_path):
    s_values = sorted({r["s"] for r in rows})
    fig, axes = plt.subplots(1, len(s_values), figsize=(6 * len(s_values), 4.2))
    if len(s_values) == 1:
        axes = [axes]
    for ax, s in zip(axes, s_values):
        series = defaultdict(list)
        for r in rows:
            if r["s"] == s:
                series[r["delta"]].append((r["t"], r["value"]))
        for delta, pts in sorted(series.items()):
            pts.sort()
            ax.plot(
                [p[0] for p in pts],
                [p[1] for p in pts],
                marker="o",
                ms=3,
                label=rf"$\delta$={delta:g}",
            )
        ax.set_xlabel("iteration t")
        ax.set_ylabel(r"$\|u_t\|^2 / k$")
        ax.set_title(f"algorithmic decoding error, BGC (s={s})")
        ax.legend(fontsize=8)
        ax.grid(alpha=0.3)
    fig.tight_layout()
    fig.savefig(out_path, dpi=130)
    plt.close(fig)
    print(f"wrote {out_path}")


def main():
    results = sys.argv[1] if len(sys.argv) > 1 else "results"
    out = sys.argv[2] if len(sys.argv) > 2 else results
    os.makedirs(out, exist_ok=True)
    specs = [
        ("fig2.csv", "one-step decoding error", r"$\mathrm{err}_1(A)/k$", "fig2.png"),
        ("fig3.csv", "optimal decoding error", r"$\mathrm{err}(A)/k$", "fig3.png"),
        ("fig4.csv", "one-step vs optimal", "error / k", "fig4.png"),
    ]
    for csv_name, title, ylabel, png in specs:
        path = os.path.join(results, csv_name)
        if os.path.exists(path):
            plot_error_vs_delta(load(path), title, ylabel, os.path.join(out, png))
        else:
            print(f"skip {csv_name} (not found; run `make figures`)")
    f5 = os.path.join(results, "fig5.csv")
    if os.path.exists(f5):
        plot_fig5(load(f5), os.path.join(out, "fig5.png"))
    else:
        print("skip fig5.csv (not found)")


if __name__ == "__main__":
    main()
